"""Pipeline entry points: raw abundance table → F statistic and p-value.

pipeline()        one study: (n, d) features + (n,) labels, all the way to
                  the permutation p-value under one PipelinePlan.
pipeline_many()   stacked studies through ONE plan (the serving scenario):
                  (S, n, d) features + (S, n) labels.

Both route stage 2 through the hardware-aware engine; stage 1 and the
bridge (dense / stream / fused) come from this package. `permanova()`
delegates here when handed features instead of a matrix, and the launch
CLI exposes it as `--from-features`.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import engine
from repro import obs as _obs
from repro.core import design as _design
from repro.data import slabcache as _slabcache
from repro.core import permutations
from repro.core.permanova import (PermanovaResult, f_from_sw,
                                  p_value_from_null)
from repro.pipeline import ordination as _ordination
from repro.pipeline import planner as _planner
from repro.pipeline import registry as _registry
from repro.pipeline import streaming as _streaming

Array = jax.Array


@functools.lru_cache(maxsize=None)
def _jit_dense(fn):
    """jit the registry's (memoized, so hashable-stable) dense distance
    callable. Eager execution re-traces any lax.map/scan inside it on
    EVERY call — the obs retrace counter flagged exactly that on warm
    dense-bridge runs."""
    return jax.jit(fn)


def _stage1_attrs(pl, dspec, n: int, d: int, bridge: str):
    """Span attrs for the distance stage: predicted traffic from the
    registry's workset model (the dense form builds one full matrix; the
    streaming form re-runs its per-slab workset once per row block), plus
    the 4n² mat2 write. None while tracing is off — the disabled path
    allocates nothing."""
    if not _obs.trace_enabled():
        return None
    block = n if bridge == "dense" else int(min(pl.row_block, n))
    n_blocks = -(-n // block)
    predicted = (float(dspec.workset_bytes(n, d, block)) * n_blocks
                 + 4.0 * n * n)
    _obs.metrics.inc("pipeline.predicted_bytes", predicted)
    return {"bridge": bridge, "impl": pl.dist_impl,
            "predicted_bytes": predicted}


def _fused_attrs(pl, n: int, d: int, n_groups: int, n_total: int, *,
                 fspec=None, studies: int = 1):
    """Span attrs for the fused bridges. The fused (two-stage) sweep
    rebuilds every D² row slab once per permutation chunk and streams the
    (chunk, n, G+1)-equivalent label state per (slab, chunk) pair; the
    fused-kernel sweep's feature traffic comes from the registry's
    precision-aware model (fp8/packed slabs shrink it)."""
    if not _obs.trace_enabled():
        return None
    block = int(min(pl.row_block, n))
    n_blocks = -(-n // block)
    ch = int(max(1, min(pl.sw.chunk, n_total)))
    n_chunks = -(-n_total // ch)
    if fspec is not None:
        predicted = (
            _registry.fused_feat_traffic_bytes(
                fspec, n, d, pl.fused_tuning, block) * n_chunks
            + 4.0 * ch * n * (n_groups + 1) * n_chunks)
        bridge, impl = "fused-kernel", fspec.name
    else:
        predicted = (4.0 * n * n
                     + n_blocks * n_chunks * 4.0 * ch * n * (n_groups + 1))
        bridge, impl = "fused", pl.sw.impl
    predicted *= studies
    _obs.metrics.inc("pipeline.predicted_bytes", predicted)
    attrs = {"bridge": bridge, "impl": impl, "predicted_bytes": predicted}
    if studies > 1:
        attrs["studies"] = studies
    return attrs


def pipeline(x: Array, grouping: Array, *, metric: str = "braycurtis",
             n_perms: int = 999, key: Optional[jax.Array] = None,
             n_groups: Optional[int] = None,
             dist_impl: str = "auto", sw_impl: str = "auto",
             materialize: str = "auto", row_block: Optional[int] = None,
             chunk: Optional[int] = None,
             memory_budget_bytes: Optional[float] = None,
             matrix_budget_bytes: Optional[float] = None,
             slab_budget_bytes: Optional[float] = None,
             dist_tuning: Optional[Dict[str, int]] = None,
             sw_tuning: Optional[Dict[str, int]] = None,
             fused_impl: str = "auto",
             fused_tuning: Optional[Dict[str, int]] = None,
             backend: Optional[str] = None,
             mesh=None,
             ordination: Optional[int] = None,
             covariates=None, strata=None, weights=None,
             autotune: bool = False,
             device_budget_bytes: Optional[float] = None,
             host_budget_bytes: Optional[float] = None,
             trace=None) -> PermanovaResult:
    """Full features→p-value PERMANOVA under one joint plan.

    x:           (n, d) abundance table (raw features, NOT distances) — or
                 a data.SlabCache (or its directory path): the feature
                 table stays on DISK and the planner grades its residency
                 tier against device_budget_bytes; below 'hbm' the sweep
                 runs out of core (async double-buffered slab prefetch
                 into the fused contraction), F/p bit-identical to the
                 in-memory bridges at the same slab boundaries.
    materialize: 'auto' | 'dense' | 'stream' | 'fused' | 'fused-kernel' —
                 whether the (n, n) matrix is built outright, streamed into
                 a single buffer, never materialized at all, or (fused-
                 kernel) swept in a single pass with distance tiles
                 contracted in-kernel.
    fused_impl:  'auto' | 'pallas' | 'xla' (or a fused registry name) —
                 which single-pass implementation runs a fused-kernel plan.
    mesh:        optional jax.sharding.Mesh with a 'model' axis — runs the
                 fused-kernel sweep multi-device (row slabs over 'model',
                 permutations over the remaining axes, psum-reduced).
                 Implies materialize='fused-kernel'.
    ordination:  optional k — also compute the top-k PCoA axes into
                 `result.ordination` (coords, eigvals, explained
                 variance). The path rides the bridge's residency
                 contract: dense eigendecomposes the Gower matrix
                 outright, stream runs the implicit-operator subspace
                 iteration against the SAME resident mat2 (no second
                 (n, n) array), and the fused bridges re-stream
                 squared-distance slabs from the features (nothing
                 (n, n)-shaped, ever).
    covariates / strata / weights: design columns (see core.design and
    core.permanova.permanova) — any of them routes through the design
    path: same joint stage-1/bridge planning, with the permutation sweep
    contracting hat-matrix basis blocks (dense designs) or strata-
    restricted labels, and per-term statistics in `result.terms`.
    `grouping` may also be a prebuilt core.design.Design.

    trace:       telemetry for this call — True enables scoped span
                 tracing + metrics (obs.session), a string additionally
                 exports the Chrome trace_event JSON to that path on
                 return; None/False (default) leaves telemetry exactly as
                 the process had it (zero overhead when off). Inspect with
                 obs.report() / obs.trace.stage_table() afterwards.

    Remaining knobs mirror engine.run(); budgets split per stage
    (matrix/slab for distances, memory_budget_bytes for s_W labels).
    For a fixed key every materialization produces the same F and p-value
    (to fp32 accumulation order).
    """
    if trace:
        with _obs.session(trace if isinstance(trace, str) else None):
            return pipeline(
                x, grouping, metric=metric, n_perms=n_perms, key=key,
                n_groups=n_groups, dist_impl=dist_impl, sw_impl=sw_impl,
                materialize=materialize, row_block=row_block, chunk=chunk,
                memory_budget_bytes=memory_budget_bytes,
                matrix_budget_bytes=matrix_budget_bytes,
                slab_budget_bytes=slab_budget_bytes,
                dist_tuning=dist_tuning, sw_tuning=sw_tuning,
                fused_impl=fused_impl, fused_tuning=fused_tuning,
                backend=backend, mesh=mesh, ordination=ordination,
                covariates=covariates, strata=strata, weights=weights,
                autotune=autotune,
                device_budget_bytes=device_budget_bytes,
                host_budget_bytes=host_budget_bytes, trace=None)
    if key is None:
        key = jax.random.key(0)
    if isinstance(x, (str, os.PathLike)):
        x = _slabcache.SlabCache.open(x)
    if isinstance(x, _slabcache.SlabCache):
        return _pipeline_ooc(
            x, grouping, metric=metric, n_perms=n_perms, key=key,
            n_groups=n_groups, dist_impl=dist_impl, sw_impl=sw_impl,
            materialize=materialize, row_block=row_block, chunk=chunk,
            memory_budget_bytes=memory_budget_bytes,
            matrix_budget_bytes=matrix_budget_bytes,
            slab_budget_bytes=slab_budget_bytes, dist_tuning=dist_tuning,
            sw_tuning=sw_tuning, fused_impl=fused_impl,
            fused_tuning=fused_tuning, backend=backend, mesh=mesh,
            ordination=ordination, covariates=covariates, strata=strata,
            weights=weights, autotune=autotune,
            device_budget_bytes=device_budget_bytes,
            host_budget_bytes=host_budget_bytes)
    x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"features must be (n, d); got shape {x.shape}")
    n, d = x.shape
    design = None
    if isinstance(grouping, _design.Design):
        if covariates is not None or strata is not None \
                or weights is not None:
            raise ValueError("pass covariates/strata/weights either to "
                             "pipeline() or inside the Design, not both")
        design = grouping
    elif covariates is not None or strata is not None or weights is not None:
        design = _design.build(
            grouping=None if grouping is None else
            jnp.asarray(grouping, jnp.int32),
            covariates=covariates, strata=strata, weights=weights,
            n_groups=n_groups, n=int(n))
    if design is not None and design.is_plain_labels:
        grouping, n_groups, design = (design.grouping, design.n_groups,
                                      None)
    if design is not None:
        return _pipeline_design(
            x, design, metric=metric, n_perms=n_perms, key=key,
            dist_impl=dist_impl, sw_impl=sw_impl, materialize=materialize,
            row_block=row_block, chunk=chunk,
            memory_budget_bytes=memory_budget_bytes,
            matrix_budget_bytes=matrix_budget_bytes,
            slab_budget_bytes=slab_budget_bytes, dist_tuning=dist_tuning,
            sw_tuning=sw_tuning, fused_impl=fused_impl,
            fused_tuning=fused_tuning, backend=backend, mesh=mesh,
            ordination=ordination, autotune=autotune)
    grouping = jnp.asarray(grouping, dtype=jnp.int32)
    if n_groups is None:
        n_groups = int(jnp.max(grouping)) + 1
    n_total = n_perms + 1

    if mesh is not None:
        if materialize not in ("auto", "fused-kernel"):
            raise ValueError(
                "mesh execution is fused-kernel only; use "
                "materialize='auto'/'fused-kernel' (or core.distributed "
                "for matrix-resident sharding)")
        materialize = "fused-kernel"

    def _plan():
        return _planner.plan_pipeline(
            n, d, n_total, n_groups, metric=metric, backend=backend,
            dist_impl=dist_impl, materialize=materialize,
            row_block=row_block, matrix_budget_bytes=matrix_budget_bytes,
            slab_budget_bytes=slab_budget_bytes,
            memory_budget_bytes=memory_budget_bytes,
            sw_impl=sw_impl, chunk=chunk, sw_tuning=sw_tuning,
            fused_impl=fused_impl, fused_tuning=fused_tuning)

    pl = _plan()
    if autotune:
        # measure only what the resolved plan actually executes; winners
        # persist per host, so replanning afterwards reads them back
        if pl.materialize == "fused-kernel" and fused_impl == "auto":
            fused_impl = _planner.autotune_fused(
                x, grouping, metric=metric, backend=backend,
                n_groups=n_groups)
            pl = _plan()
        elif pl.materialize in ("dense", "stream") and dist_impl == "auto":
            # never for 'fused': the stage-1 shoot-out builds full dense
            # matrices, exactly the allocation that bridge exists to avoid
            dist_impl = _planner.autotune_stage1(x, metric, backend=backend)
            pl = _plan()
    dspec = _registry.get(pl.dist_impl)
    # planner-resolved tuning (row block folded in) <- caller overrides
    prepare, rows_fn, dense_fn = dspec.bound(
        **{**pl.dist_tuning, **(dist_tuning or {})})

    ordn = None
    if pl.materialize == "dense":
        with _obs.span(f"stage1.{metric}",
                       _stage1_attrs(pl, dspec, n, d, "dense")):
            dm = _obs.maybe_block(_jit_dense(dense_fn)(x))
        res = engine.run(dm, grouping, n_perms=n_perms, key=key,
                         n_groups=n_groups, impl=sw_impl,
                         memory_budget_bytes=memory_budget_bytes,
                         chunk=chunk, autotune=autotune, backend=backend,
                         tuning=sw_tuning)
        if ordination is not None:
            # the dense bridge already budgets (n, n) transients; the
            # centered matrix + eigh is the exact path
            with _obs.span("pipeline.pcoa"):
                ordn = _ordination.pcoa_eigh(dm * dm, ordination)
    elif pl.materialize == "stream":
        with _obs.span(f"stage1.{metric}",
                       _stage1_attrs(pl, dspec, n, d, "stream")):
            mat2, gower = _streaming.build_mat2_streaming(
                prepare(x), rows_fn, block=pl.row_block)
            mat2_dev = jnp.asarray(mat2)
        del mat2   # free the host buffer: ONE sustained (n, n) resident
                   # (the handoff copy itself is transiently 2x; the fused
                   # bridge is the option that never holds (n, n) at all)
        res = engine.run(mat2_dev, grouping, n_perms=n_perms,
                         key=key, n_groups=n_groups, impl=sw_impl,
                         memory_budget_bytes=memory_budget_bytes,
                         chunk=chunk, autotune=autotune, backend=backend,
                         tuning=sw_tuning, squared=True, s_t=gower.s_t)
        if ordination is not None:
            # implicit centered operator against the SAME resident mat2 +
            # the marginals the streaming pass already accumulated — the
            # Gower matrix itself is never materialized (one (n, n) array
            # stays the bridge's contract)
            with _obs.span("pipeline.pcoa"):
                ordn = _ordination.pcoa_subspace(mat2_dev, ordination,
                                                 stats=gower)
    elif pl.materialize == "fused":
        if autotune:
            warnings.warn(
                "autotune=True ignored: the fused bridge computes s_W in "
                "its one-hot matmul form (use materialize='stream'/'dense' "
                "to let measurements pick the s_W impl, or "
                "materialize='fused-kernel' for the measured single-pass "
                "candidates)", stacklevel=2)
        inv_gs = permutations.inv_group_sizes(grouping, n_groups)
        xprep = prepare(x)
        with _obs.span("bridge.fused",
                       _fused_attrs(pl, n, d, n_groups, n_total)):
            s_w, s_t, stats = _streaming.fused_sw(
                xprep, rows_fn, grouping, inv_gs, key, n_total,
                row_block=pl.row_block, chunk=pl.sw.chunk)
            s_w = _obs.maybe_block(s_w)
        f_all = f_from_sw(jnp.asarray(s_w, jnp.float32),
                          jnp.float32(s_t), n, n_groups)
        res = PermanovaResult(
            f_stat=f_all[0], p_value=p_value_from_null(f_all),
            s_t=jnp.float32(s_t), s_w=jnp.asarray(s_w[0], jnp.float32),
            f_perms=f_all, n_objects=n, n_groups=n_groups, n_perms=n_perms,
            method="pipeline[fused]",
            plan=(f"rows={stats.row_block}x{stats.n_row_blocks} "
                  f"chunks={stats.n_chunks} slab="
                  f"{stats.peak_slab_bytes/2**20:.1f}MiB"))
    elif pl.materialize == "fused-kernel":
        inv_gs = permutations.inv_group_sizes(grouping, n_groups)
        fspec = _registry.get_fused(pl.fused_impl)
        xprep = prepare(x)
        with _obs.span("bridge.fused-kernel",
                       _fused_attrs(pl, n, d, n_groups, n_total,
                                    fspec=fspec)):
            if mesh is not None:
                if fspec.kind != "xla" and fused_impl not in (None, "auto"):
                    warnings.warn(
                        f"mesh execution runs the XLA fused sweep; pinned "
                        f"fused_impl={fused_impl!r} is not used",
                        stacklevel=2)
                s_w, s_t, stats = _streaming.fused_sw_sharded(
                    mesh, xprep, rows_fn, grouping, inv_gs, key, n_total,
                    row_block=pl.row_block, chunk=pl.sw.chunk)
            else:
                s_w, s_t, stats = _streaming.fused_kernel_sw(
                    xprep, rows_fn, grouping, inv_gs, key, n_total,
                    impl=fspec.kind, kernel_metric=fspec.kernel_metric,
                    row_block=pl.row_block, chunk=pl.sw.chunk,
                    tuning=pl.fused_tuning)
            s_w = _obs.maybe_block(s_w)
        _obs.record_device_memory()
        f_all = f_from_sw(jnp.asarray(s_w, jnp.float32),
                          jnp.float32(s_t), n, n_groups)
        res = PermanovaResult(
            f_stat=f_all[0], p_value=p_value_from_null(f_all),
            s_t=jnp.float32(s_t), s_w=jnp.asarray(s_w[0], jnp.float32),
            f_perms=f_all, n_objects=n, n_groups=n_groups, n_perms=n_perms,
            method="pipeline[fused-kernel]",
            plan=(f"{stats.impl}{'+mesh' if mesh is not None else ''} "
                  f"rows={stats.row_block} chunks={stats.n_chunks} "
                  f"slab={stats.peak_slab_bytes/2**20:.2f}MiB "
                  f"labels={stats.peak_label_bytes/2**20:.2f}MiB"))
    else:  # pragma: no cover - planner validates
        raise ValueError(pl.materialize)

    if ordination is not None and ordn is None:
        # fused bridges: every matvec of the subspace iteration re-streams
        # squared-distance row slabs from the feature table — ordination
        # inherits the fused contract (nothing (n, n)-shaped ever exists);
        # xprep was bound by the fused branch above
        with _obs.span("pipeline.pcoa"):
            ordn = _ordination.pcoa_features(xprep, rows_fn, ordination,
                                             row_block=pl.row_block)

    if pl.materialize in ("fused", "fused-kernel"):
        # the fused bridge IS stage 2; the joint plan string is authoritative
        executed_sw = pl.sw.impl
        plan_str = f"{pl.describe()} :: {res.plan}"
    else:
        # engine.run planned stage 2 (autotune may have overridden ours) —
        # report its record once instead of a possibly-contradicting copy
        executed_sw = (res.method.split("[", 1)[1].rstrip("]")
                       if "[" in res.method else pl.sw.impl)
        plan_str = f"{pl.describe_stage1()} | {pl.reason} :: {res.plan}"
    return dataclasses.replace(
        res,
        method=f"pipeline[{pl.dist_impl}->{pl.materialize}->{executed_sw}]",
        plan=plan_str, ordination=ordn)


def _pipeline_ooc(cache: "_slabcache.SlabCache", grouping, *, metric: str,
                  n_perms: int, key, n_groups, dist_impl, sw_impl,
                  materialize, row_block, chunk, memory_budget_bytes,
                  matrix_budget_bytes, slab_budget_bytes, dist_tuning,
                  sw_tuning, fused_impl, fused_tuning, backend, mesh,
                  ordination, covariates, strata, weights, autotune,
                  device_budget_bytes, host_budget_bytes
                  ) -> PermanovaResult:
    """pipeline() when the features live in a slab cache.

    The planner grades the residency tier from the f32 footprint: 'hbm'
    loads the cache once and reruns the ordinary in-memory path (same
    plan, same programs); 'host'/'disk' run the out-of-core sweep — the
    async prefetcher stages slab k+1 while slab k's distance tiles are
    assembled and contracted by the UNCHANGED fused steps, so F/p are
    bit-identical to the in-memory bridges at row_block == slab_rows.
    """
    n, d = cache.n, cache.d
    n_total = n_perms + 1
    if mesh is not None:
        raise ValueError("slab-cache features run single-device; mesh "
                         "execution needs the resident table")
    if cache.fmt == "csr" and metric != "jaccard":
        raise ValueError(
            f"csr slab caches store presence structure only; metric "
            f"{metric!r} needs the dense format (jaccard reads it)")

    design = None
    if isinstance(grouping, _design.Design):
        if covariates is not None or strata is not None \
                or weights is not None:
            raise ValueError("pass covariates/strata/weights either to "
                             "pipeline() or inside the Design, not both")
        design = grouping
    elif covariates is not None or strata is not None or weights is not None:
        design = _design.build(
            grouping=None if grouping is None else
            jnp.asarray(grouping, jnp.int32),
            covariates=covariates, strata=strata, weights=weights,
            n_groups=n_groups, n=n)
    if design is not None and design.is_plain_labels:
        grouping, n_groups, design = (design.grouping, design.n_groups,
                                      None)
    dense_mode = design is not None and design.mode == _design.MODE_DENSE
    k = design.k_cols if dense_mode else None
    if design is None:
        grouping = jnp.asarray(grouping, jnp.int32)
        if n_groups is None:
            n_groups = int(jnp.max(grouping)) + 1
        n_groups_plan = n_groups
    else:
        if design.n != n:
            raise ValueError(f"design is for n={design.n}, cache is "
                             f"({n}, {d})")
        n_groups_plan = (design.n_groups if design.n_groups is not None
                         else design.rank)

    pl = _planner.plan_pipeline(
        n, d, n_total, n_groups_plan, metric=metric, backend=backend,
        dist_impl=dist_impl, materialize=materialize, row_block=row_block,
        matrix_budget_bytes=matrix_budget_bytes,
        slab_budget_bytes=slab_budget_bytes,
        memory_budget_bytes=memory_budget_bytes, sw_impl=sw_impl,
        chunk=chunk, sw_tuning=sw_tuning, fused_impl=fused_impl,
        fused_tuning=fused_tuning, design_cols=k, features_on_disk=True,
        slab_rows=cache.slab_rows, features_disk_bytes=cache.disk_bytes,
        device_budget_bytes=device_budget_bytes,
        host_budget_bytes=host_budget_bytes)

    if pl.residency == "hbm":
        # the f32 table fits the device budget: stream the cache into
        # memory ONCE and run the ordinary resident path
        res = pipeline(
            cache.to_array(), grouping if design is None else design,
            metric=metric, n_perms=n_perms, key=key, n_groups=n_groups,
            dist_impl=dist_impl, sw_impl=sw_impl, materialize=materialize,
            row_block=row_block, chunk=chunk,
            memory_budget_bytes=memory_budget_bytes,
            matrix_budget_bytes=matrix_budget_bytes,
            slab_budget_bytes=slab_budget_bytes, dist_tuning=dist_tuning,
            sw_tuning=sw_tuning, fused_impl=fused_impl,
            fused_tuning=fused_tuning, backend=backend,
            ordination=ordination, autotune=autotune)
        return dataclasses.replace(
            res, plan=f"{res.plan} | features=slab-cache(residency=hbm)")

    if ordination is not None:
        raise ValueError(
            "ordination needs resident features; raise "
            "device_budget_bytes (residency must reach 'hbm') or run it "
            "separately on a subsample")
    if autotune:
        warnings.warn(
            "autotune=True ignored out of core: the shoot-outs run on "
            "resident operands", stacklevel=3)

    dspec = _registry.get(pl.dist_impl)
    prepare, rows_fn, _ = dspec.bound(
        **{**pl.dist_tuning, **(dist_tuning or {})})
    onepass = pl.materialize == "fused-kernel"

    span_attrs = None
    if _obs.trace_enabled():
        predicted = _registry.ooc_disk_traffic_bytes(cache.n_slabs,
                                                     cache.disk_bytes)
        _obs.metrics.inc("pipeline.predicted_bytes", predicted)
        span_attrs = {"bridge": f"ooc-{pl.materialize}",
                      "residency": pl.residency,
                      "predicted_bytes": predicted}
    with _obs.span("bridge.ooc", span_attrs):
        if design is None:
            inv_gs = permutations.inv_group_sizes(grouping, n_groups)
            s_w, s_t, ost = _streaming.fused_sw_ooc(
                cache, rows_fn, prepare, grouping, inv_gs, key, n_total,
                chunk=pl.sw.chunk, onepass=onepass)
        elif dense_mode:
            s_cols, s_t, ost = _streaming.fused_sw_ooc_design(
                cache, rows_fn, prepare, design, key, n_total,
                chunk=pl.sw.chunk, onepass=onepass)
        else:
            inv_gs = permutations.inv_group_sizes(design.grouping,
                                                  design.n_groups)
            s_w, s_t, ost = _streaming.fused_sw_ooc(
                cache, rows_fn, prepare, design.grouping, inv_gs, key,
                n_total, chunk=pl.sw.chunk, strata=design.strata,
                onepass=onepass)
        if span_attrs is not None:
            # the span's attrs merge at __exit__, so the measured overlap
            # evidence lands in the trace artifact
            span_attrs["stall_ms"] = round(ost.stall_s * 1e3, 3)
            span_attrs["disk_bytes_read"] = ost.disk_bytes_read
    _obs.record_device_memory()

    sweep = (f"residency={pl.residency} slabs={ost.n_slabs}"
             f"x{ost.slab_rows} chunks={ost.n_chunks} "
             f"read={ost.disk_bytes_read/2**20:.1f}MiB "
             f"stall={ost.stall_s*1e3:.1f}ms/{ost.sweep_s*1e3:.0f}ms")
    if design is None:
        f_all = f_from_sw(jnp.asarray(s_w, jnp.float32),
                          jnp.float32(s_t), n, n_groups)
        res = PermanovaResult(
            f_stat=f_all[0], p_value=p_value_from_null(f_all),
            s_t=jnp.float32(s_t), s_w=jnp.asarray(s_w[0], jnp.float32),
            f_perms=f_all, n_objects=n, n_groups=n_groups,
            n_perms=n_perms, method=f"pipeline[ooc-{pl.materialize}]",
            plan=sweep)
    elif dense_mode:
        res = engine.design_result(
            jnp.asarray(s_cols, jnp.float32), design, n_objects=n,
            n_perms=n_perms,
            method=f"pipeline-design[ooc-{pl.materialize}]", plan=sweep)
    else:
        res = engine.api.label_design_result(
            jnp.asarray(s_w, jnp.float32), jnp.float32(s_t), design,
            n_objects=n, n_perms=n_perms,
            method=f"pipeline[ooc-{pl.materialize}+strata]",
            plan=f"{sweep} strata")
    return dataclasses.replace(res, plan=f"{pl.describe()} :: {res.plan}")


def _pipeline_design(x: Array, design: "_design.Design", *, metric: str,
                     n_perms: int, key, dist_impl: str, sw_impl: str,
                     materialize: str, row_block, chunk,
                     memory_budget_bytes, matrix_budget_bytes,
                     slab_budget_bytes, dist_tuning, sw_tuning,
                     fused_impl, fused_tuning, backend, mesh, ordination,
                     autotune: bool) -> PermanovaResult:
    """features→per-term p-values for a non-plain design.

    Every materialization bridge keeps its residency contract: dense and
    stream hand the (squared-)distance matrix to engine.run_design;
    the fused bridges contract basis blocks (dense designs) or
    strata-restricted labels against D² row slabs exactly as the label
    sweep does — nothing about the memory-bound dataflow changes, only
    the right-hand-side operand.
    """
    n, d = (int(v) for v in x.shape)
    if design.n != n:
        raise ValueError(f"design is for n={design.n}, features are "
                         f"({n}, {d})")
    if mesh is not None:
        raise ValueError(
            "single-study mesh execution supports plain single-factor "
            "designs only; shard design studies over the 'data' axis via "
            "pipeline_many/permanova_many instead")
    n_total = n_perms + 1
    dense_mode = design.mode == _design.MODE_DENSE
    k = design.k_cols if dense_mode else None
    n_groups_plan = (design.n_groups if design.n_groups is not None
                     else design.rank)

    def _plan():
        return _planner.plan_pipeline(
            n, d, n_total, n_groups_plan, metric=metric, backend=backend,
            dist_impl=dist_impl, materialize=materialize,
            row_block=row_block, matrix_budget_bytes=matrix_budget_bytes,
            slab_budget_bytes=slab_budget_bytes,
            memory_budget_bytes=memory_budget_bytes,
            sw_impl=sw_impl, chunk=chunk, sw_tuning=sw_tuning,
            fused_impl=fused_impl, fused_tuning=fused_tuning,
            design_cols=k)

    pl = _plan()
    if autotune and pl.materialize in ("dense", "stream") \
            and dist_impl == "auto":
        dist_impl = _planner.autotune_stage1(x, metric, backend=backend)
        pl = _plan()
    dspec = _registry.get(pl.dist_impl)
    prepare, rows_fn, dense_fn = dspec.bound(
        **{**pl.dist_tuning, **(dist_tuning or {})})

    ordn = None
    xprep = None
    if pl.materialize == "dense":
        with _obs.span(f"stage1.{metric}",
                       _stage1_attrs(pl, dspec, n, d, "dense")):
            dm = _obs.maybe_block(_jit_dense(dense_fn)(x))
        res = engine.run_design(
            dm, design, n_perms=n_perms, key=key, impl=sw_impl,
            memory_budget_bytes=memory_budget_bytes, chunk=chunk,
            backend=backend, tuning=sw_tuning)
        if ordination is not None:
            with _obs.span("pipeline.pcoa"):
                ordn = _ordination.pcoa_eigh(dm * dm, ordination)
    elif pl.materialize == "stream":
        with _obs.span(f"stage1.{metric}",
                       _stage1_attrs(pl, dspec, n, d, "stream")):
            mat2, gower = _streaming.build_mat2_streaming(
                prepare(x), rows_fn, block=pl.row_block)
            mat2_dev = jnp.asarray(mat2)
        del mat2
        res = engine.run_design(
            mat2_dev, design, n_perms=n_perms, key=key, impl=sw_impl,
            memory_budget_bytes=memory_budget_bytes, chunk=chunk,
            backend=backend, tuning=sw_tuning, squared=True,
            s_t=gower.s_t)
        if ordination is not None:
            ordn = _ordination.pcoa_subspace(mat2_dev, ordination,
                                             stats=gower)
    elif pl.materialize == "fused":
        xprep = prepare(x)
        if dense_mode:
            with _obs.span("bridge.fused",
                           _fused_attrs(pl, n, d, n_groups_plan, n_total)):
                s_cols, _, stats = _streaming.fused_sw_design(
                    xprep, rows_fn, design, key, n_total,
                    row_block=pl.row_block, chunk=pl.sw.chunk)
            res = engine.design_result(
                jnp.asarray(s_cols, jnp.float32), design, n_objects=n,
                n_perms=n_perms, method="pipeline-design[fused]",
                plan=(f"rows={stats.row_block}x{stats.n_row_blocks} "
                      f"chunks={stats.n_chunks} cols={k}"))
        else:
            inv_gs = permutations.inv_group_sizes(design.grouping,
                                                  design.n_groups)
            with _obs.span("bridge.fused",
                           _fused_attrs(pl, n, d, n_groups_plan, n_total)):
                s_w, s_t, stats = _streaming.fused_sw(
                    xprep, rows_fn, design.grouping, inv_gs, key, n_total,
                    row_block=pl.row_block, chunk=pl.sw.chunk,
                    strata=design.strata)
            res = engine.api.label_design_result(
                jnp.asarray(s_w, jnp.float32), jnp.float32(s_t), design,
                n_objects=n, n_perms=n_perms,
                method="pipeline[fused+strata]",
                plan=(f"rows={stats.row_block}x{stats.n_row_blocks} "
                      f"chunks={stats.n_chunks} strata"))
    elif pl.materialize == "fused-kernel":
        fspec = _registry.get_fused(pl.fused_impl)
        xprep = prepare(x)
        if dense_mode:
            with _obs.span("bridge.fused-kernel",
                           _fused_attrs(pl, n, d, n_groups_plan, n_total,
                                        fspec=fspec)):
                s_cols, _, stats = _streaming.fused_kernel_sw_design(
                    xprep, rows_fn, design, key, n_total, impl=fspec.kind,
                    kernel_metric=fspec.kernel_metric,
                    row_block=pl.row_block, chunk=pl.sw.chunk,
                    tuning=pl.fused_tuning)
            res = engine.design_result(
                jnp.asarray(s_cols, jnp.float32), design, n_objects=n,
                n_perms=n_perms,
                method=f"pipeline-design[fused-kernel:{stats.impl}]",
                plan=(f"{stats.impl} rows={stats.row_block} "
                      f"chunks={stats.n_chunks} cols={k}"))
        else:
            inv_gs = permutations.inv_group_sizes(design.grouping,
                                                  design.n_groups)
            with _obs.span("bridge.fused-kernel",
                           _fused_attrs(pl, n, d, n_groups_plan, n_total,
                                        fspec=fspec)):
                s_w, s_t, stats = _streaming.fused_kernel_sw(
                    xprep, rows_fn, design.grouping, inv_gs, key, n_total,
                    impl=fspec.kind, kernel_metric=fspec.kernel_metric,
                    row_block=pl.row_block, chunk=pl.sw.chunk,
                    tuning=pl.fused_tuning, strata=design.strata)
            res = engine.api.label_design_result(
                jnp.asarray(s_w, jnp.float32), jnp.float32(s_t), design,
                n_objects=n, n_perms=n_perms,
                method=f"pipeline[fused-kernel:{stats.impl}+strata]",
                plan=(f"{stats.impl} rows={stats.row_block} "
                      f"chunks={stats.n_chunks} strata"))
    else:  # pragma: no cover - planner validates
        raise ValueError(pl.materialize)

    if ordination is not None and ordn is None:
        with _obs.span("pipeline.pcoa"):
            ordn = _ordination.pcoa_features(xprep, rows_fn, ordination,
                                             row_block=pl.row_block)
    return dataclasses.replace(
        res,
        plan=f"{pl.describe_stage1()} | {pl.reason} :: {res.plan} "
             f"({design.describe()})",
        ordination=ordn)


# ---------------------------------------------------------------------------
# Batched multi-study pipeline (serving scenario).
# ---------------------------------------------------------------------------

def pipeline_many(xs: Array, groupings: Array, *, n_groups: int,
                  metric: str = "braycurtis", n_perms: int = 999,
                  key: Optional[jax.Array] = None,
                  dist_impl: str = "auto", sw_impl: str = "auto",
                  materialize: str = "auto",
                  row_block: Optional[int] = None,
                  chunk: Optional[int] = None,
                  memory_budget_bytes: Optional[float] = None,
                  matrix_budget_bytes: Optional[float] = None,
                  backend: Optional[str] = None,
                  mesh=None,
                  covariates=None, strata=None, weights=None,
                  ordination: Optional[int] = None
                  ) -> engine.PermanovaManyResult:
    """Stacked studies features→p-values through ONE joint plan.

    xs:         (S, n, d) abundance tables.
    groupings:  (S, n) int labels in [0, n_groups) (shared design width,
                like engine.permanova_many).
    materialize: 'auto' | 'dense' | 'fused-kernel'. The dense path builds
                the (S, n, n) stack study-by-study (lax.map bounds peak
                distance transients to one study's) and runs the engine's
                vmapped program; the fused-kernel path vmaps the single-
                pass sweep — nothing (n, n)-shaped ever exists, per-study
                peak residency (row_block, n). 'auto' picks fused-kernel
                exactly when the stack would blow the matrix budget.
    mesh:       optional Mesh with a 'data' axis — shards the STUDY axis
                over 'data' (fused-kernel only). Permutation draws fold
                the key by GLOBAL study index before sharding, so every
                study's null is independent and sharded == single-host ==
                S separate pipeline() calls, regardless of which shard
                runs it.
    ordination: optional k — per-study top-k PCoA axes into
                `result.ordination` (engine.PermanovaManyResult is the
                shared multi-study contract: F, p, R^2, coordinates +
                explained variance). The dense path eigendecomposes from
                the distance stack; the fused-kernel path re-streams
                squared-distance slabs from the features per study, so
                nothing (n, n)-shaped is added to its footprint.

    covariates / strata / weights: stacked per-study design columns —
    (S, n, c) / (S, n) arrays (see engine.permanova_many). Any of them
    routes the batch through the dense-design program: the dense bridge
    builds the distance stack and delegates, the fused-kernel bridge
    vmaps the per-column basis contraction over the study axis (still
    nothing (n, n)-shaped), shardable over 'data'.

    Study s draws its null from fold_in(key, s) — identical to S
    independent pipeline() calls — on EVERY path; a single fold must never
    be reused across the batch axis.
    """
    if key is None:
        key = jax.random.key(0)
    xs = jnp.asarray(xs)
    if xs.ndim != 3:
        raise ValueError(f"stacked features must be (S, n, d); "
                         f"got shape {xs.shape}")
    groupings = jnp.asarray(groupings, dtype=jnp.int32)
    s_count, n, d = xs.shape
    n_total = n_perms + 1
    stack_bytes = 4 * s_count * n * n
    budget = (_planner.DEFAULT_MATRIX_BUDGET_BYTES
              if matrix_budget_bytes is None else matrix_budget_bytes)

    if mesh is not None and materialize not in ("auto", "fused-kernel"):
        raise ValueError("mesh execution of pipeline_many is fused-kernel "
                         "only; use materialize='auto'/'fused-kernel'")
    if materialize == "auto":
        materialize = ("fused-kernel"
                       if mesh is not None or stack_bytes > budget
                       else "dense")
    if materialize not in ("dense", "fused-kernel"):
        raise ValueError(
            f"pipeline_many supports materialize='dense'/'fused-kernel' "
            f"(got {materialize!r}); stream/fused are single-study bridges")

    designed = (covariates is not None or strata is not None
                or weights is not None)
    if designed and materialize == "dense":
        pl = _planner.plan_pipeline(
            n, d, n_total, n_groups, metric=metric, backend=backend,
            dist_impl=dist_impl, row_block=row_block, materialize="dense",
            matrix_budget_bytes=matrix_budget_bytes,
            memory_budget_bytes=memory_budget_bytes, chunk=chunk)
        dspec = _registry.get(pl.dist_impl)
        _, _, dense_fn = dspec.bound(**pl.dist_tuning)
        dms = jax.lax.map(dense_fn, xs)
        res = engine.permanova_many(
            dms, groupings, n_groups=n_groups, n_perms=n_perms, key=key,
            chunk=chunk, memory_budget_bytes=memory_budget_bytes,
            backend=backend, mesh=mesh, covariates=covariates,
            strata=strata, weights=weights, ordination=ordination)
        res.plan = f"{pl.dist_impl} -> dense(batched lax.map) -> {res.plan}"
        return res
    if designed:
        return _pipeline_many_fused_design(
            xs, groupings, covariates=covariates, strata=strata,
            weights=weights, n_groups=n_groups, metric=metric,
            n_perms=n_perms, key=key, row_block=row_block, chunk=chunk,
            memory_budget_bytes=memory_budget_bytes, backend=backend,
            mesh=mesh, ordination=ordination)

    if materialize == "fused-kernel":
        return _pipeline_many_fused(
            xs, groupings, n_groups=n_groups, metric=metric,
            n_perms=n_perms, key=key, row_block=row_block, chunk=chunk,
            memory_budget_bytes=memory_budget_bytes, backend=backend,
            mesh=mesh, ordination=ordination)

    pl = _planner.plan_pipeline(
        n, d, n_total, n_groups, metric=metric, backend=backend,
        dist_impl=dist_impl, row_block=row_block, materialize="dense",
        matrix_budget_bytes=matrix_budget_bytes,
        memory_budget_bytes=memory_budget_bytes,
        sw_impl=sw_impl, chunk=chunk)
    if stack_bytes > budget:
        warnings.warn(
            f"pipeline_many materializes the full (S, n, n) stack "
            f"({stack_bytes/2**20:.0f}MiB), exceeding the matrix budget "
            f"({budget/2**20:.0f}MiB); use materialize='fused-kernel' "
            "(never builds the stack) or split the studies", stacklevel=2)
    dspec = _registry.get(pl.dist_impl)
    _, _, dense_fn = dspec.bound(**pl.dist_tuning)

    dms = jax.lax.map(dense_fn, xs)        # one study's transients at a time
    res = engine.permanova_many(
        dms, groupings, n_groups=n_groups, n_perms=n_perms, key=key,
        impl=sw_impl, chunk=chunk,
        memory_budget_bytes=memory_budget_bytes, backend=backend,
        ordination=ordination)
    res.plan = (f"{pl.dist_impl} -> dense(batched lax.map) -> "
                f"{res.plan}")
    return res


@functools.lru_cache(maxsize=64)
def _fused_many_program(metric: str, block: int, ch: int, n_chunks: int,
                        n: int, pad: int, n_groups: int):
    """The jitted vmapped fused sweep, cached per static config — serving
    callers must not pay a fresh trace/compile of the scan-of-scans per
    request (mirrors engine.api._many_program)."""
    from repro.core import distance as _dist
    mdef = _dist.ROW_METRICS[metric]

    def one(xp_pad, xp, grouping, igs, study_key):
        return _streaming._sweep_rows_perms(
            xp_pad, xp, grouping, igs, study_key, jnp.int32(0),
            jnp.int32(0), rows_fn=mdef.rows, block=block, chunk=ch,
            n_chunks=n_chunks, n=n, n_rows_pad=n + pad, n_groups=n_groups)

    return jax.jit(jax.vmap(one))


def _pipeline_many_fused(xs: Array, groupings: Array, *, n_groups: int,
                         metric: str, n_perms: int, key: jax.Array,
                         row_block: Optional[int], chunk: Optional[int],
                         memory_budget_bytes: Optional[float],
                         backend: Optional[str],
                         mesh,
                         ordination: Optional[int] = None
                         ) -> engine.PermanovaManyResult:
    """Batched single-pass sweep: vmap of the fused-kernel dataflow over
    the study axis, optionally sharded over the mesh's 'data' axis.

    Per-study keys are folded by GLOBAL study index BEFORE any sharding —
    the stacked studies each draw an independent null exactly as S
    separate pipeline() calls would (a single fold reused across the
    batch axis would correlate every study's permutations).
    """
    from repro.core import distance as _dist
    s_count, n, d = (int(v) for v in xs.shape)
    n_total = n_perms + 1

    # joint plan for ONE study; the vmap holds every study's chunk state
    # live at once, so the label budget splits S ways (engine convention)
    total_budget = (engine.planner.DEFAULT_STREAM_BUDGET_BYTES
                    if memory_budget_bytes is None else memory_budget_bytes)
    # the batched sweep always executes the XLA form (vmapped scan-of-
    # scans) — pin the plan to it so the recorded impl matches execution
    pl = _planner.plan_pipeline(
        n, d, n_total, n_groups, metric=metric, backend=backend,
        materialize="fused-kernel", fused_impl="xla", row_block=row_block,
        memory_budget_bytes=total_budget / s_count, chunk=chunk)
    mdef = _dist.ROW_METRICS[metric]
    xs_prep = mdef.prepare(xs)             # every prepare is last-axis-local
    block = int(min(pl.row_block, n))
    ch = int(max(1, min(pl.sw.chunk, n_total)))
    n_chunks = -(-n_total // ch)
    pad = (-n) % block
    xs_pad = jnp.pad(xs_prep, ((0, 0), (0, pad), (0, 0)))
    inv_gs = jax.vmap(
        lambda g: permutations.inv_group_sizes(g, n_groups))(groupings)
    run = _fused_many_program(metric, block, ch, n_chunks, n, pad,
                              n_groups)

    study_idx = jnp.arange(s_count)
    args = (xs_pad, xs_prep, groupings, inv_gs)
    where = "vmap"
    # study counts that do not divide 'data' wrap-pad and slice, the same
    # contract as engine.permanova_many (shared helper)
    data_ways, s_pad, wrap_idx = engine.api.study_axis_padding(mesh,
                                                              s_count)
    if wrap_idx is not None:
        args = tuple(jnp.take(a, wrap_idx, axis=0) for a in args)
        study_idx = wrap_idx
    # GLOBAL study index -> per-study key, folded before any sharding;
    # a padded slot replays its source study's key, so the pad is inert
    study_keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(study_idx)
    args = args + (study_keys,)
    if data_ways > 1:
        args = engine.api.put_study_sharded(mesh, args)
        where = (f"vmap@data[{data_ways}]"
                 + (f"+pad{s_pad}" if s_pad else ""))
    with _obs.span("bridge.fused-kernel",
                   _fused_attrs(pl, n, d, n_groups, n_total,
                                fspec=_registry.get_fused(pl.fused_impl),
                                studies=s_count)):
        s_w_all, rs = _obs.maybe_block(run(*args))  # (S', n_chunks*ch)
    _obs.metrics.inc("engine.studies", s_count)
    _obs.record_device_memory()
    s_w_all = s_w_all[:s_count, :n_total]
    s_t = jnp.sum(rs[:s_count, :n], axis=1) / 2.0 / n
    f_perms = jax.vmap(f_from_sw, in_axes=(0, 0, None, None))(
        s_w_all, s_t.astype(jnp.float32), n, n_groups)
    p_vals = jax.vmap(p_value_from_null)(f_perms)

    ord_res = None
    if ordination is not None:
        # per-study streamed PCoA (unsharded, deterministic — identical
        # embeddings whether or not the sweep above ran on a mesh);
        # lax.map bounds transients to ONE study's subspace iterate, and
        # the Gower marginals reuse the sweep's row sums (`rs`) instead
        # of paying another full distance rebuild per study
        from repro.pipeline import ordination as _ord

        def one_pcoa(xp_rs):
            xp, rs_s = xp_rs
            stats = _streaming.GowerStats(row_sums=rs_s,
                                          total=jnp.sum(rs_s), n=n)
            r = _ord.pcoa_features(xp, mdef.rows, int(ordination),
                                   row_block=block, stats=stats)
            return r.coords, r.eigvals, r.explained

        coords, eigvals, explained = jax.lax.map(
            one_pcoa, (xs_prep, rs[:s_count, :n]))
        ord_res = _ord.PCoAResult(coords=coords, eigvals=eigvals,
                                  explained=explained,
                                  method="subspace-stream")

    return engine.PermanovaManyResult(
        f_stat=f_perms[:, 0], p_value=p_vals, s_t=s_t.astype(jnp.float32),
        s_w=s_w_all[:, 0], f_perms=f_perms, n_objects=n, n_groups=n_groups,
        n_perms=n_perms, ordination=ord_res,
        plan=(f"{pl.fused_impl}({where}) rows={block} "
              f"chunk={ch} studies={s_count} chunks={n_chunks} | "
              f"{pl.reason}"))


@functools.lru_cache(maxsize=64)
def _fused_many_program_design(metric: str, block: int, ch: int,
                               n_chunks: int, n: int, pad: int, k: int):
    """The jitted vmapped fused DESIGN sweep, cached per static config
    (mirrors _fused_many_program): per study, the chunk scan draws
    strata-restricted index permutations, gathers basis rows, and runs
    the per-column contraction against D² row slabs built in-scan."""
    from repro.core import distance as _dist
    mdef = _dist.ROW_METRICS[metric]

    def one(xp_pad, xp, basis, strata, study_key):
        return _streaming._sweep_rows_perms_design(
            xp_pad, xp, basis, strata, study_key, jnp.int32(0),
            jnp.int32(0), rows_fn=mdef.rows, block=block, chunk=ch,
            n_chunks=n_chunks, n=n, n_rows_pad=n + pad, k_cols=k)

    return jax.jit(jax.vmap(one))


def _pipeline_many_fused_design(xs: Array, groupings: Array, *,
                                covariates, strata, weights,
                                n_groups: int, metric: str, n_perms: int,
                                key: jax.Array, row_block, chunk,
                                memory_budget_bytes, backend, mesh,
                                ordination) -> engine.PermanovaManyResult:
    """Batched single-pass DESIGN sweep: vmap of the fused dense-basis
    dataflow over the study axis, optionally sharded over 'data'.

    Per-study keys fold by GLOBAL study index before any sharding, so
    sharded == single-host == S separate pipeline() calls bit-identically
    (including strata-restricted draws)."""
    from repro.core import distance as _dist
    s_count, n, d = (int(v) for v in xs.shape)
    n_total = n_perms + 1

    designs = engine.api._build_study_designs(
        groupings, covariates, strata, weights, n_groups=n_groups, n=n,
        s_count=s_count)
    d0 = designs[0]
    k = d0.k_cols
    basis_stack = jnp.stack([dd.basis for dd in designs])
    strata_stack = jnp.stack([
        dd.strata if dd.strata is not None else jnp.zeros((n,), jnp.int32)
        for dd in designs])

    total_budget = (engine.planner.DEFAULT_STREAM_BUDGET_BYTES
                    if memory_budget_bytes is None else memory_budget_bytes)
    pl = _planner.plan_pipeline(
        n, d, n_total, n_groups, metric=metric, backend=backend,
        materialize="fused-kernel", fused_impl="xla", row_block=row_block,
        memory_budget_bytes=total_budget / s_count, chunk=chunk,
        design_cols=k)
    mdef = _dist.ROW_METRICS[metric]
    xs_prep = mdef.prepare(xs)
    block = int(min(pl.row_block, n))
    ch = int(max(1, min(pl.sw.chunk, n_total)))
    n_chunks = -(-n_total // ch)
    pad = (-n) % block
    xs_pad = jnp.pad(xs_prep, ((0, 0), (0, pad), (0, 0)))
    run = _fused_many_program_design(metric, block, ch, n_chunks, n, pad,
                                     k)

    study_idx = jnp.arange(s_count)
    args = (xs_pad, xs_prep, basis_stack, strata_stack)
    where = "vmap"
    data_ways, s_pad, wrap_idx = engine.api.study_axis_padding(mesh,
                                                              s_count)
    if wrap_idx is not None:
        args = tuple(jnp.take(a, wrap_idx, axis=0) for a in args)
        study_idx = wrap_idx
    study_keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(study_idx)
    args = args + (study_keys,)
    if data_ways > 1:
        args = engine.api.put_study_sharded(mesh, args)
        where = (f"vmap@data[{data_ways}]"
                 + (f"+pad{s_pad}" if s_pad else ""))
    with _obs.span("bridge.fused-kernel",
                   _fused_attrs(pl, n, d, n_groups, n_total,
                                fspec=_registry.get_fused(pl.fused_impl),
                                studies=s_count)):
        s_cols_all, rs = _obs.maybe_block(run(*args))  # (S', nc*ch, K)
    _obs.metrics.inc("engine.studies", s_count)
    _obs.record_device_memory()
    s_cols = s_cols_all[:s_count, :n_total]

    ord_res = None
    if ordination is not None:
        from repro.pipeline import ordination as _ord

        def one_pcoa(xp_rs):
            xp, rs_s = xp_rs
            stats = _streaming.GowerStats(row_sums=rs_s,
                                          total=jnp.sum(rs_s), n=n)
            r = _ord.pcoa_features(xp, mdef.rows, int(ordination),
                                   row_block=block, stats=stats)
            return r.coords, r.eigvals, r.explained

        coords, eigvals, explained = jax.lax.map(
            one_pcoa, (xs_prep, rs[:s_count, :n]))
        ord_res = _ord.PCoAResult(coords=coords, eigvals=eigvals,
                                  explained=explained,
                                  method="subspace-stream")

    dof_resid = jnp.full((s_count,), n - d0.rank, jnp.float32)
    return engine.api.design_many_result(
        s_cols, d0, dof_resid=dof_resid, n_objects=n, n_groups=n_groups,
        n_perms=n_perms, ordination=ord_res,
        plan=(f"{pl.fused_impl}({where}) rows={block} chunk={ch} "
              f"studies={s_count} cols={k} chunks={n_chunks} | "
              f"{pl.reason} ({d0.describe()})"))
