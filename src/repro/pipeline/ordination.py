"""PCoA ordination — the consumer for the pipeline's Gower marginals.

Principal Coordinates Analysis (classical MDS) embeds the samples of a
distance matrix into k dimensions: eigendecompose the Gower-centered
matrix G = -1/2 J (D∘D) J (J the centering projector) and scale the top
eigenvectors by sqrt(eigenvalue). PERMANOVA and PCoA share ALL of their
expensive inputs — mat2 = D∘D and its Gower marginals (row sums / grand
sum), which the streaming builder already accumulates — so ordination
rides the pipeline's dataflow instead of re-deriving it.

Three execution paths, chosen by what is resident:

  pcoa_eigh       dense eigendecomposition of G. Builds G outright — only
                  appropriate where an extra (n, n) transient is already
                  within budget (the pipeline's 'dense' bridge).
  pcoa_subspace   subspace (orthogonal/block-power) iteration against an
                  IMPLICIT centered operator: G @ V is evaluated from
                  mat2 @ V plus rank-1 corrections built from the Gower
                  marginals, so G itself is never materialized. This is
                  the 'stream' bridge's path — mat2 stays the only (n, n)
                  array resident.
  pcoa_features   the same subspace iteration with mat2 @ V itself
                  streamed: every matvec rebuilds squared-distance row
                  slabs from the (n, d) feature table (the fused bridges'
                  path — nothing (n, n)-shaped ever exists).

The centered operator is indefinite for semi-metrics (Bray-Curtis,
Jaccard), and plain power iteration converges to the largest |lambda| —
possibly a NEGATIVE eigenvalue. The subspace paths therefore first
estimate the spectral radius rho with a short power iteration and then
iterate on the SHIFTED operator G + rho I (all eigenvalues >= 0, order
preserved), recovering the true eigenvalues by a Rayleigh-Ritz step
against the unshifted operator.

Conventions (shared by every path, asserted by the parity tests):
  * eigenvalues descending; coordinates coords[:, i] = v_i * sqrt(max
    (lambda_i, 0)) — non-positive axes embed as zero width.
  * explained[i] = lambda_i / trace(G), and trace(G) == s_T (the
    PERMANOVA total sum of squares) — so "explained variance" is the
    fraction of the total dispersion the axis carries. Semi-metrics can
    make individual ratios exceed 1 (negative eigenvalues elsewhere in
    the spectrum); we report the raw ratio rather than renormalizing.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.pipeline.streaming import (GowerStats, _mat2_rows_step,
                                      _pad_rows, gower_center)

Array = jax.Array

DEFAULT_ITERS = 96
DEFAULT_OVERSAMPLE = 8


@dataclasses.dataclass
class PCoAResult:
    """Top-k principal coordinates. Arrays may carry a leading study axis
    (the stacked permanova_many / pipeline_many consumers)."""
    coords: Array          # (..., n, k) sample coordinates
    eigvals: Array         # (..., k) descending eigenvalues of G
    explained: Array       # (..., k) eigval / trace(G) == eigval / s_T
    method: str            # 'eigh' | 'subspace' | 'subspace-stream'

    @property
    def k(self) -> int:
        return int(self.coords.shape[-1])

    def study(self, s: int) -> "PCoAResult":
        """View one study of a stacked result."""
        return PCoAResult(coords=self.coords[s], eigvals=self.eigvals[s],
                          explained=self.explained[s], method=self.method)


# ---------------------------------------------------------------------------
# Implicit centered operator: G @ V from mat2 @ V + Gower marginals.
# ---------------------------------------------------------------------------

def centered_matvec(matvec: Callable[[Array], Array], row_sums: Array,
                    total: Array, n, valid: Optional[Array] = None
                    ) -> Callable[[Array], Array]:
    """Wrap V -> mat2 @ V into V -> G @ V without materializing G.

    G = -1/2 (M - r 1^T/n - 1 r^T/n + t/n^2 1 1^T) gives

      G @ V = -1/2 (M @ V - (r/n) colsum(V) - 1 (r^T V)/n
                    + (t/n^2) 1 colsum(V))

    with r/t the Gower marginals the streaming pass accumulates. `n` is
    the number of VALID samples (may be traced); `valid` masks pad rows
    of a padded study — the rank-1 terms are constant across rows, so
    the mask must be applied to the OUTPUT, not just the inputs.
    """
    r = jnp.asarray(row_sums, jnp.float32)
    t = jnp.float32(total)

    def gv(v: Array) -> Array:
        vv = v if valid is None else v * valid[:, None]
        mv = matvec(vv)
        cs = jnp.sum(vv, axis=0)                       # (k,) column sums
        rv = r @ vv                                    # (k,)
        out = -0.5 * (mv - r[:, None] * (cs[None, :] / n)
                      - rv[None, :] / n + (t / (n * n)) * cs[None, :])
        return out if valid is None else out * valid[:, None]

    return gv


def _spectral_radius(gv: Callable, n: int, key: jax.Array,
                     iters: int = 16) -> Array:
    """Power-iteration estimate of ||G||_2 (largest |eigenvalue|)."""
    v = jax.random.normal(key, (n, 1), jnp.float32)
    v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)

    def body(carry, _):
        v, _ = carry
        w = gv(v)
        nrm = jnp.linalg.norm(w)
        return (w / jnp.maximum(nrm, 1e-30), nrm), None

    (v, rho), _ = jax.lax.scan(body, (v, jnp.float32(0.0)), None,
                               length=iters)
    return rho


def subspace_eigs(gv: Callable[[Array], Array], n: int, k: int, *,
                  iters: int = DEFAULT_ITERS,
                  oversample: int = DEFAULT_OVERSAMPLE,
                  key: Optional[jax.Array] = None,
                  valid: Optional[Array] = None,
                  tol: float = 1e-8):
    """Top-k (eigenvalues desc, eigenvectors (n, k)) of the implicit
    symmetric operator `gv`, by shifted orthogonal iteration.

    Early exit: the loop stops once the shifted Rayleigh quotients
    stagnate (relative change <= tol) — typically well under `iters`
    steps, which matters most on the feature-streamed path where every
    matvec rebuilds the distance slabs; `iters` is the hard cap.
    Deterministic for a fixed key (default key(0)): sharded and
    single-host callers produce identical embeddings. `valid` confines
    the iterate to the valid-sample subspace of a padded study.
    """
    if key is None:
        key = jax.random.key(0)
    p = int(min(n, k + oversample))
    rho = _spectral_radius(gv, n, jax.random.fold_in(key, 1))
    shift = rho * 1.05 + 1e-12      # strictly dominate any negative tail

    def gv_shifted(v):
        vv = v if valid is None else v * valid[:, None]
        return gv(vv) + shift * vv

    v0 = jax.random.normal(jax.random.fold_in(key, 0), (n, p), jnp.float32)
    if valid is not None:
        v0 = v0 * valid[:, None]
    q0, _ = jnp.linalg.qr(gv_shifted(v0))

    def cond(carry):
        _, _, i, done = carry
        return (i < iters) & ~done

    def body(carry):
        v, rq_prev, i, _ = carry
        w = gv_shifted(v)
        rq = jnp.sum(v * w, axis=0)        # shifted Rayleigh quotients
        q, _ = jnp.linalg.qr(w)
        scale = jnp.maximum(jnp.max(jnp.abs(rq)), 1e-30)
        done = jnp.max(jnp.abs(rq - rq_prev)) <= tol * scale
        return q, rq, i + 1, done

    v, _, _, _ = jax.lax.while_loop(
        cond, body, (q0, jnp.full((p,), jnp.inf, jnp.float32),
                     jnp.int32(0), jnp.bool_(False)))
    # Rayleigh-Ritz against the UNSHIFTED operator: eigenvalues come out
    # directly, no shift subtraction (and no rho error) in the result.
    b = v.T @ gv(v)
    b = 0.5 * (b + b.T)
    evals, evecs = jnp.linalg.eigh(b)                  # ascending
    order = jnp.argsort(-evals)[:k]
    return evals[order], v @ evecs[:, order]


def _coords_from_eigs(evals: Array, evecs: Array, s_t: Array) -> PCoAResult:
    lam = jnp.maximum(evals, 0.0)
    coords = evecs * jnp.sqrt(lam)[None, :]
    explained = evals / s_t
    return PCoAResult(coords=coords, eigvals=evals, explained=explained,
                      method="")


# ---------------------------------------------------------------------------
# Execution paths.
# ---------------------------------------------------------------------------

def pcoa_eigh(mat2: Array, k: int, *,
              stats: Optional[GowerStats] = None) -> PCoAResult:
    """Dense path: materialize G and eigendecompose it outright.

    Costs one extra (n, n) transient — the 'dense' bridge's ordination
    (where D and mat2 transients were already in budget). This is also
    the oracle the subspace paths are tested against.
    """
    mat2 = jnp.asarray(mat2, jnp.float32)
    n = mat2.shape[0]
    g = gower_center(mat2, stats)
    s_t = jnp.trace(g)                                  # == s_T exactly
    evals, evecs = jnp.linalg.eigh(g)                   # ascending
    order = jnp.argsort(-evals)[: int(min(k, n))]
    res = _coords_from_eigs(evals[order], evecs[:, order], s_t)
    return dataclasses.replace(res, method="eigh")


def pcoa_subspace(mat2: Array, k: int, *,
                  stats: Optional[GowerStats] = None,
                  iters: int = DEFAULT_ITERS,
                  oversample: int = DEFAULT_OVERSAMPLE,
                  key: Optional[jax.Array] = None) -> PCoAResult:
    """Implicit path for a RESIDENT mat2: G is never materialized — the
    'stream' bridge keeps its single-(n, n)-array contract."""
    mat2 = jnp.asarray(mat2, jnp.float32)
    n = int(mat2.shape[0])
    if stats is None:
        rs = jnp.sum(mat2, axis=1)
        total = jnp.sum(rs)
    else:
        rs = jnp.asarray(stats.row_sums, jnp.float32)
        total = jnp.float32(stats.total)
    gv = centered_matvec(lambda v: mat2 @ v, rs, total, n)
    evals, evecs = subspace_eigs(gv, n, int(min(k, n)), iters=iters,
                                 oversample=oversample, key=key)
    res = _coords_from_eigs(evals, evecs, total / 2.0 / n)
    return dataclasses.replace(res, method="subspace")


@functools.partial(jax.jit, static_argnames=("rows_fn", "block", "n"))
def _streamed_matvec_step(xpad, xprep, v, *, rows_fn, block, n):
    """(mat2 @ V, row_sums) in one slab sweep — nothing (n, n) resident."""
    n_pad = xpad.shape[0]

    def body(_, lo):
        m2 = _mat2_rows_step(xpad, xprep, lo, rows_fn=rows_fn,
                             block=block, n=n)
        return None, (m2 @ v, jnp.sum(m2, axis=1))

    _, (mv, rs) = jax.lax.scan(body, None,
                               jnp.arange(n_pad // block) * block)
    return mv.reshape(n_pad, -1)[:n], rs.reshape(-1)[:n]


def pcoa_features(xprep: Array, rows_fn: Callable, k: int, *,
                  row_block: int,
                  stats: Optional[GowerStats] = None,
                  iters: int = DEFAULT_ITERS,
                  oversample: int = DEFAULT_OVERSAMPLE,
                  key: Optional[jax.Array] = None) -> PCoAResult:
    """Fully-streamed path for the fused bridges: every matvec rebuilds
    the squared-distance row slabs from the prepared feature table, so
    ordination inherits the fused contract — peak residency is one
    (row_block, n) slab, never an (n, n) array.

    The Gower marginals come free from the first sweep when the caller
    has none (the fused bridges only retain s_T).
    """
    n = int(xprep.shape[0])
    block = int(min(row_block, n))
    xpad, _ = _pad_rows(xprep, block)
    step = functools.partial(_streamed_matvec_step, xpad, xprep,
                             rows_fn=rows_fn, block=block, n=n)
    if stats is None:
        _, rs = step(jnp.zeros((n, 1), jnp.float32))
        total = jnp.sum(rs)
    else:
        rs = jnp.asarray(stats.row_sums, jnp.float32)
        total = jnp.float32(stats.total)
    gv = centered_matvec(lambda v: step(v)[0], rs, total, n)
    evals, evecs = subspace_eigs(gv, n, int(min(k, n)), iters=iters,
                                 oversample=oversample, key=key)
    res = _coords_from_eigs(evals, evecs, total / 2.0 / n)
    return dataclasses.replace(res, method="subspace-stream")


def pcoa_many(dms: Array, k: int, *,
              n_valid: Optional[Array] = None,
              iters: int = DEFAULT_ITERS,
              oversample: int = DEFAULT_OVERSAMPLE,
              key: Optional[jax.Array] = None) -> PCoAResult:
    """Stacked-study PCoA from an (S, n, n) distance stack.

    lax.map over studies bounds peak transients to ONE study's mat2 (the
    stack itself is caller-resident; we never hold a second (S, n, n)
    array). `n_valid` (S,) masks ragged studies padded to a common n —
    pad coordinates come out exactly zero.
    """
    dms = jnp.asarray(dms, jnp.float32)
    s_count, n, _ = dms.shape
    k = int(min(k, n))
    if key is None:
        key = jax.random.key(0)

    def one(args):
        dm, nv = args
        mat2 = dm * dm
        if n_valid is None:     # static: skip the masking on stacked input
            vmask = None
        else:
            vmask = (jnp.arange(n) < nv).astype(jnp.float32)
            mat2 = mat2 * vmask[:, None] * vmask[None, :]
        rs = jnp.sum(mat2, axis=1)
        total = jnp.sum(rs)
        gv = centered_matvec(lambda v: mat2 @ v, rs, total, nv, valid=vmask)
        evals, evecs = subspace_eigs(gv, n, k, iters=iters,
                                     oversample=oversample, key=key,
                                     valid=vmask)
        lam = jnp.maximum(evals, 0.0)
        return evals, evecs * jnp.sqrt(lam)[None, :], total / 2.0 / nv

    nv = (jnp.full((s_count,), n, jnp.float32) if n_valid is None
          else jnp.asarray(n_valid, jnp.float32))
    evals, coords, s_t = jax.lax.map(one, (dms, nv))
    return PCoAResult(coords=coords, eigvals=evals,
                      explained=evals / s_t[:, None],
                      method="subspace")
