"""End-to-end distance→PERMANOVA pipeline subsystem.

Takes a raw abundance table (n, d) plus grouping labels all the way to
F-statistics and p-values under ONE plan:

  registry    every distance implementation (dense jnp metrics, blocked
              row-streaming builders, Pallas tiled kernels) behind one
              interface with capability metadata — the stage-1 mirror of
              repro.engine.registry
  planner     joint two-stage plans: distance impl + row block, the
              materialization bridge (dense / stream / fused), and the
              engine's s_W plan, decided together
  streaming   the bridge implementations: mat2 row-block producer, the
              never-resident-twice streaming builder (+ Gower marginals),
              and the fused distance→s_W driver
  ordination  PCoA consumer for the Gower marginals: dense eigh, the
              implicit-operator subspace iteration (no centered matrix),
              and the feature-streamed matvec path for the fused bridges
  api         pipeline() single study, pipeline_many() stacked studies

Entry points routing here: core.permanova.permanova(features, metric=...),
the launch CLI's --from-features/--pcoa, examples/emp_scale_permanova.py,
and the pipeline benchmark suite.
"""

from repro.pipeline import (api, ordination, planner,  # noqa: F401
                            registry, streaming)
from repro.pipeline.api import pipeline, pipeline_many  # noqa: F401
from repro.pipeline.ordination import (PCoAResult, pcoa_eigh,  # noqa: F401
                                       pcoa_features, pcoa_many,
                                       pcoa_subspace)
from repro.pipeline.planner import (DEFAULT_MATRIX_BUDGET_BYTES,  # noqa: F401
                                    PipelinePlan, autotune_fused,
                                    autotune_stage1, plan_pipeline)
from repro.pipeline.registry import (DistanceImpl, FusedImpl,  # noqa: F401
                                     fused_names, get, get_fused, metrics,
                                     names)
from repro.pipeline.streaming import (FusedKernelStats,  # noqa: F401
                                      FusedStats, GowerStats,
                                      build_mat2_streaming, fused_kernel_sw,
                                      fused_kernel_sw_design, fused_sw,
                                      fused_sw_design, fused_sw_onepass,
                                      fused_sw_sharded, gower_center,
                                      mat2_row_blocks)
