"""Streaming distance construction + fused distance→s_W execution.

Three materialization strategies for getting from an (n, d) table to the
squared-distance operand `mat2 = D∘D` the s_W engine consumes:

  dense    build D, hand it to the engine (which squares it) — D and mat2
           are both resident transiently. Cheapest to trace; fine while
           8n² bytes fit.
  stream   produce D row blocks, square + diagonal-mask them ON DEVICE as
           they are emitted, and accumulate into ONE host mat2 buffer —
           the raw distance matrix D is never materialized, and only one
           (n, n) array is SUSTAINED (the device handoff copy is a
           transient 2x; on unified-memory APUs it is the same physical
           pages). Gower marginals (row sums / grand sum) are accumulated
           in the same pass, so s_T and the centered form come free.
  fused    never materialize (n, n) at all: each mat2 row block feeds the
           streaming permutation scheduler's chunks directly (row-partial
           s_W in the one-hot matmul form), with labels regenerated on
           device per chunk by the same global-index key folding the
           engine scheduler uses. Peak residency is one (row_block, n)
           slab + one (chunk, n) label block, independent of n.

The fused partial is the Gower-centered trace statistic in disguise:
s_W over row blocks is exactly the blockwise trace form of Anderson's
centered inner-product matrix, so consuming mat2 blocks as produced IS
streaming into the centering — no second pass over the matrix.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fstat, permutations

Array = jax.Array


class GowerStats(NamedTuple):
    """Marginals of mat2 accumulated during the streaming pass."""
    row_sums: np.ndarray   # (n,) float64 — sum_j mat2[i, j]
    total: float           # sum_ij mat2[i, j]
    n: int

    @property
    def s_t(self) -> float:
        """s_T = sum_{i<j} d²/n = total / 2 / n (zero diagonal)."""
        return self.total / 2.0 / self.n


def gower_center(mat2: Array, stats: Optional[GowerStats] = None) -> Array:
    """Gower-centered matrix G = -1/2 (mat2 - rowmean - colmean + grandmean).

    PERMANOVA's s_T/s_W are trace forms over G; the engine consumes mat2
    directly, but ordination-style consumers (PCoA) want G itself."""
    n = mat2.shape[0]
    if stats is None:
        rs = jnp.sum(mat2, axis=1)
        total = jnp.sum(rs)
    else:
        rs = jnp.asarray(stats.row_sums, mat2.dtype)
        total = stats.total
    rm = rs[:, None] / n
    cm = rs[None, :] / n
    return -0.5 * (mat2 - rm - cm + total / (n * n))


# ---------------------------------------------------------------------------
# Row-block producer: one jitted step serves every block of the sweep.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("rows_fn", "block", "n"))
def _mat2_rows_step(xprep_pad, xprep, lo, *, rows_fn, block, n):
    """mat2 rows for GLOBAL rows [lo, lo+block): distance slab, squared,
    with pad rows and the exact diagonal zeroed. `lo` is traced, so one
    compiled program serves every block."""
    d = xprep_pad.shape[1]
    xb = jax.lax.dynamic_slice(xprep_pad, (lo, 0), (block, d))
    drows = rows_fn(xb, xprep)                       # (block, n)
    row_ids = lo + jnp.arange(block)
    valid = (row_ids < n)[:, None] & (row_ids[:, None]
                                      != jnp.arange(n)[None, :])
    return jnp.where(valid, drows * drows, 0.0)


def _pad_rows(xprep: Array, block: int):
    n = xprep.shape[0]
    pad = (-n) % block
    if pad:
        return jnp.pad(xprep, ((0, pad), (0, 0))), n + pad
    return xprep, n


def mat2_row_blocks(xprep: Array, rows_fn: Callable, *, block: int):
    """Yield (lo, mat2_rows) device slabs covering rows [0, n) in order.

    The last slab is block-sized with zeroed pad rows; consumers slice
    [:n - lo] or rely on the zero contract."""
    n = int(xprep.shape[0])
    block = int(min(block, n))
    xpad, n_pad = _pad_rows(xprep, block)
    for lo in range(0, n_pad, block):
        yield lo, _mat2_rows_step(xpad, xprep, jnp.int32(lo),
                                  rows_fn=rows_fn, block=block, n=n)


def build_mat2_streaming(xprep: Array, rows_fn: Callable, *, block: int):
    """mat2 via the streaming producer: ONE (n, n) buffer, filled blockwise.

    D itself is never materialized — each row slab is squared and masked on
    device, then written into the single host-side mat2 buffer. Returns
    (mat2 float32 ndarray, GowerStats accumulated in the same pass). The
    caller should release this buffer once it is handed to the device
    (pipeline's stream bridge does) so only one (n, n) array is sustained.
    """
    n = int(xprep.shape[0])
    mat2 = np.empty((n, n), np.float32)
    row_sums = np.zeros((n,), np.float64)
    for lo, slab in mat2_row_blocks(xprep, rows_fn, block=block):
        hi = min(lo + slab.shape[0], n)
        rows = np.asarray(slab[: hi - lo])
        mat2[lo:hi] = rows
        row_sums[lo:hi] = rows.sum(axis=1, dtype=np.float64)
    return mat2, GowerStats(row_sums=row_sums, total=float(row_sums.sum()),
                            n=n)


# ---------------------------------------------------------------------------
# Fused distance → s_W: mat2 row blocks feed permutation chunks directly.
# ---------------------------------------------------------------------------

class FusedStats(NamedTuple):
    """Execution evidence: how the fused sweep actually ran."""
    n_total: int
    chunk: int
    n_chunks: int
    row_block: int
    n_row_blocks: int
    peak_slab_bytes: int     # (row_block, n) mat2 slab — the live matrix
    peak_label_bytes: int    # (chunk, n) labels


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block", "n", "n_groups"))
def _fused_sw_step(m2rows, grouping, inv_gs, key, lo_r, lo_p, *,
                   chunk, block, n, n_groups):
    """Row-partial s_W (fstat's matmul-form contraction) for permutation
    indices [lo_p, lo_p+chunk), over mat2 rows [lo_r, lo_r+block).

    Labels are regenerated on device by global-index key folding (identical
    to the engine scheduler), so every (row block × perm chunk) cell of the
    sweep is independent and the results sum exactly to the full statistic.
    Pad rows carry zeroed mat2 rows, so their (arbitrary) labels contribute
    nothing; the row-label slice comes from a zero-padded label block so the
    slice window never clamps out of alignment."""
    g = permutations.permutation_batch_dyn(key, grouping, lo_p, chunk)
    e = fstat.onehot_perm_factors(g, inv_gs, m2rows.dtype)   # (P, n, G)
    e_pad = jnp.pad(e, ((0, 0), (0, (-n) % block), (0, 0)))
    e_rows = jax.lax.dynamic_slice(e_pad, (0, lo_r, 0),
                                   (chunk, block, n_groups))
    return fstat.sw_matmul_contract(m2rows, e, e_rows)


def fused_sw(xprep: Array, rows_fn: Callable, grouping: Array,
             inv_gs: Array, key: jax.Array, n_total: int, *,
             row_block: int, chunk: int,
             progress: Optional[Callable[[int, int], None]] = None):
    """s_W for permutation indices [0, n_total) without ever holding the
    (n, n) matrix: outer loop over mat2 row slabs (each built once), inner
    loop over permutation chunks consuming the live slab.

    Returns (s_w float64 ndarray (n_total,), s_t float, FusedStats).
    """
    n = int(xprep.shape[0])
    n_groups = int(inv_gs.shape[0])
    row_block = int(min(row_block, n))
    chunk = int(max(1, min(chunk, n_total)))
    grouping = jnp.asarray(grouping, jnp.int32)
    out = np.zeros((n_total,), np.float64)
    s_t_sum = 0.0
    n_row_blocks = 0
    for lo_r, slab in mat2_row_blocks(xprep, rows_fn, block=row_block):
        n_row_blocks += 1
        s_t_sum += float(jnp.sum(slab))      # s_T marginal, once per slab
        for lo_p in range(0, n_total, chunk):
            sw = _fused_sw_step(
                slab, grouping, inv_gs, key, jnp.int32(lo_r),
                jnp.int32(lo_p), chunk=chunk, block=slab.shape[0], n=n,
                n_groups=n_groups)
            hi = min(lo_p + chunk, n_total)
            out[lo_p:hi] += np.asarray(sw[: hi - lo_p], np.float64)
        if progress is not None:
            progress(min(lo_r + row_block, n), n)
    stats = FusedStats(
        n_total=n_total, chunk=chunk, n_chunks=-(-n_total // chunk),
        row_block=row_block, n_row_blocks=n_row_blocks,
        peak_slab_bytes=4 * row_block * n,
        peak_label_bytes=4 * chunk * n)
    return out, s_t_sum / 2.0 / n, stats
