"""Streaming distance construction + fused distance→s_W execution.

Four materialization strategies for getting from an (n, d) table to the
squared-distance operand `mat2 = D∘D` the s_W engine consumes:

  dense    build D, hand it to the engine (which squares it) — D and mat2
           are both resident transiently. Cheapest to trace; fine while
           8n² bytes fit.
  stream   produce D row blocks, square + diagonal-mask them ON DEVICE as
           they are emitted, and accumulate into ONE host mat2 buffer —
           the raw distance matrix D is never materialized, and only one
           (n, n) array is SUSTAINED (the device handoff copy is a
           transient 2x; on unified-memory APUs it is the same physical
           pages). Gower marginals (row sums / grand sum) are accumulated
           in the same pass, so s_T and the centered form come free.
  fused    never materialize (n, n) at all: each mat2 row block feeds the
           streaming permutation scheduler's chunks directly (row-partial
           s_W in the one-hot matmul form), with labels regenerated on
           device per chunk by the same global-index key folding the
           engine scheduler uses. Peak residency is one (row_block, n)
           slab + one (chunk, n) label block, independent of n.

  fused-kernel
           the single-pass form of `fused`: distance construction and the
           s_W contraction execute inside ONE program, so the D² slab is
           not round-tripped through HBM between two dispatches and the
           sweep pays no per-cell host sync. Two implementations behind
           the same driver (`fused_kernel_sw`): the Pallas megakernel
           (kernels.fused_sw — D² tiles live only in VMEM) and a one-jit
           XLA scan-of-scans (`fused_sw_onepass`) for backends without a
           kernel path. `fused_sw_sharded` runs the same dataflow over a
           device mesh: row slabs shard the 'model' axis, permutations
           shard the remaining axes, partials psum-reduced — mirroring
           core.distributed, but without ever building the matrix.

The fused partial is the Gower-centered trace statistic in disguise:
s_W over row blocks is exactly the blockwise trace form of Anderson's
centered inner-product matrix, so consuming mat2 blocks as produced IS
streaming into the centering — no second pass over the matrix.
"""

from __future__ import annotations

import functools
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import obs as _obs
from repro.core import distance as _dist
from repro.core import fstat, permutations

try:  # jax >= 0.5 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

Array = jax.Array


class GowerStats(NamedTuple):
    """Marginals of mat2 accumulated during the streaming pass."""
    row_sums: np.ndarray   # (n,) float64 — sum_j mat2[i, j]
    total: float           # sum_ij mat2[i, j]
    n: int

    @property
    def s_t(self) -> float:
        """s_T = sum_{i<j} d²/n = total / 2 / n (zero diagonal)."""
        return self.total / 2.0 / self.n


def gower_center(mat2: Array, stats: Optional[GowerStats] = None) -> Array:
    """Gower-centered matrix G = -1/2 (mat2 - rowmean - colmean + grandmean).

    PERMANOVA's s_T/s_W are trace forms over G; the engine consumes mat2
    directly, but ordination-style consumers (PCoA) want G itself."""
    n = mat2.shape[0]
    if stats is None:
        rs = jnp.sum(mat2, axis=1)
        total = jnp.sum(rs)
    else:
        rs = jnp.asarray(stats.row_sums, mat2.dtype)
        total = stats.total
    rm = rs[:, None] / n
    cm = rs[None, :] / n
    return -0.5 * (mat2 - rm - cm + total / (n * n))


# ---------------------------------------------------------------------------
# Row-block producer: one jitted step serves every block of the sweep.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("rows_fn", "block", "n"))
def _mat2_rows_step(xprep_pad, xprep, lo, *, rows_fn, block, n):
    """mat2 rows for GLOBAL rows [lo, lo+block): distance slab, squared,
    with pad rows and the exact diagonal zeroed. `lo` is traced, so one
    compiled program serves every block."""
    d = xprep_pad.shape[1]
    xb = jax.lax.dynamic_slice(xprep_pad, (lo, 0), (block, d))
    drows = rows_fn(xb, xprep)                       # (block, n)
    row_ids = lo + jnp.arange(block)
    valid = (row_ids < n)[:, None] & (row_ids[:, None]
                                      != jnp.arange(n)[None, :])
    return jnp.where(valid, drows * drows, 0.0)


def _pad_rows(xprep: Array, block: int):
    n = xprep.shape[0]
    pad = (-n) % block
    if pad:
        return jnp.pad(xprep, ((0, pad), (0, 0))), n + pad
    return xprep, n


def mat2_row_blocks(xprep: Array, rows_fn: Callable, *, block: int):
    """Yield (lo, mat2_rows) device slabs covering rows [0, n) in order.

    The last slab is block-sized with zeroed pad rows; consumers slice
    [:n - lo] or rely on the zero contract."""
    n = int(xprep.shape[0])
    block = int(min(block, n))
    xpad, n_pad = _pad_rows(xprep, block)
    for lo in range(0, n_pad, block):
        yield lo, _mat2_rows_step(xpad, xprep, jnp.int32(lo),
                                  rows_fn=rows_fn, block=block, n=n)


def build_mat2_streaming(xprep: Array, rows_fn: Callable, *, block: int):
    """mat2 via the streaming producer: ONE (n, n) buffer, filled blockwise.

    D itself is never materialized — each row slab is squared and masked on
    device, then written into the single host-side mat2 buffer. Returns
    (mat2 float32 ndarray, GowerStats accumulated in the same pass). The
    caller should release this buffer once it is handed to the device
    (pipeline's stream bridge does) so only one (n, n) array is sustained.
    """
    n = int(xprep.shape[0])
    mat2 = np.empty((n, n), np.float32)
    row_sums = np.zeros((n,), np.float64)
    for lo, slab in mat2_row_blocks(xprep, rows_fn, block=block):
        with _obs.span("stream.mat2_block", {"lo": lo}):
            hi = min(lo + slab.shape[0], n)
            # np.asarray is the device sync for this slab — inside the span
            rows = np.asarray(slab[: hi - lo])
            mat2[lo:hi] = rows
            row_sums[lo:hi] = rows.sum(axis=1, dtype=np.float64)
    _obs.metrics.inc("pipeline.mat2_bytes_built", 4.0 * n * n)
    return mat2, GowerStats(row_sums=row_sums, total=float(row_sums.sum()),
                            n=n)


# ---------------------------------------------------------------------------
# Fused distance → s_W: mat2 row blocks feed permutation chunks directly.
# ---------------------------------------------------------------------------

class FusedStats(NamedTuple):
    """Execution evidence: how the fused sweep actually ran."""
    n_total: int
    chunk: int
    n_chunks: int
    row_block: int
    n_row_blocks: int
    peak_slab_bytes: int     # (row_block, n) mat2 slab — the live matrix
    peak_label_bytes: int    # (chunk, n) labels


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block", "n", "n_groups"))
def _fused_sw_step(m2rows, grouping, strata, inv_gs, key, lo_r, lo_p, *,
                   chunk, block, n, n_groups):
    """Row-partial s_W (fstat's matmul-form contraction) for permutation
    indices [lo_p, lo_p+chunk), over mat2 rows [lo_r, lo_r+block).

    Labels are regenerated on device by global-index key folding (identical
    to the engine scheduler), so every (row block × perm chunk) cell of the
    sweep is independent and the results sum exactly to the full statistic.
    `strata=None` is the free generator — byte-identical to the pre-design
    sweep (None traces a distinct program); an array restricts draws within
    blocks. Pad rows carry zeroed mat2 rows, so their (arbitrary) labels
    contribute nothing; the row-label slice comes from a zero-padded label
    block so the slice window never clamps out of alignment."""
    if strata is None:
        g = permutations.permutation_batch_dyn(key, grouping, lo_p, chunk)
    else:
        g = permutations.strata_label_batch_dyn(key, grouping, strata,
                                                lo_p, chunk)
    e = fstat.onehot_perm_factors(g, inv_gs, m2rows.dtype)   # (P, n, G)
    e_pad = jnp.pad(e, ((0, 0), (0, (-n) % block), (0, 0)))
    e_rows = jax.lax.dynamic_slice(e_pad, (0, lo_r, 0),
                                   (chunk, block, n_groups))
    return fstat.sw_matmul_contract(m2rows, e, e_rows)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block", "n", "k_cols",
                                    "groups"))
def _fused_sw_step_cols(m2rows, basis, strata, key, lo_r, lo_p, *,
                        chunk, block, n, k_cols, groups=()):
    """Dense-design cousin of _fused_sw_step: strata-restricted index
    permutations gather basis rows; the per-column contraction returns a
    (chunk, K) partial over this row slab. `groups` (static, from
    fstat.sparse_col_groups) switches to the block-sparse gather form —
    exact, because dropped terms are structural zeros."""
    perms = permutations.strata_permutation_batch_dyn(key, strata, lo_p,
                                                      chunk)
    v = fstat.basis_perm_factors(basis, perms)               # (P, n, K)
    v_pad = jnp.pad(v, ((0, 0), (0, (-n) % block), (0, 0)))
    v_rows = jax.lax.dynamic_slice(v_pad, (0, lo_r, 0),
                                   (chunk, block, k_cols))
    if groups:
        return fstat.sw_cols_contract_sparse(m2rows, v, v_rows, groups)
    return fstat.sw_cols_contract(m2rows, v, v_rows)


def fused_sw(xprep: Array, rows_fn: Callable, grouping: Array,
             inv_gs: Array, key: jax.Array, n_total: int, *,
             row_block: int, chunk: int,
             strata: Optional[Array] = None,
             progress: Optional[Callable[[int, int], None]] = None):
    """s_W for permutation indices [0, n_total) without ever holding the
    (n, n) matrix: outer loop over mat2 row slabs (each built once), inner
    loop over permutation chunks consuming the live slab.

    Returns (s_w float64 ndarray (n_total,), s_t float, FusedStats).
    """
    n = int(xprep.shape[0])
    n_groups = int(inv_gs.shape[0])
    row_block = int(min(row_block, n))
    chunk = int(max(1, min(chunk, n_total)))
    grouping = jnp.asarray(grouping, jnp.int32)
    out = np.zeros((n_total,), np.float64)
    s_t_sum = 0.0
    n_row_blocks = 0
    for lo_r, slab in mat2_row_blocks(xprep, rows_fn, block=row_block):
        with _obs.span("fused.row_slab", {"lo": lo_r}):
            n_row_blocks += 1
            s_t_sum += float(jnp.sum(slab))  # s_T marginal, once per slab
            for lo_p in range(0, n_total, chunk):
                sw = _fused_sw_step(
                    slab, grouping, strata, inv_gs, key, jnp.int32(lo_r),
                    jnp.int32(lo_p), chunk=chunk, block=slab.shape[0], n=n,
                    n_groups=n_groups)
                hi = min(lo_p + chunk, n_total)
                out[lo_p:hi] += np.asarray(sw[: hi - lo_p], np.float64)
        if progress is not None:
            progress(min(lo_r + row_block, n), n)
    stats = FusedStats(
        n_total=n_total, chunk=chunk, n_chunks=-(-n_total // chunk),
        row_block=row_block, n_row_blocks=n_row_blocks,
        peak_slab_bytes=4 * row_block * n,
        peak_label_bytes=4 * chunk * n)
    _obs.metrics.inc("fused.row_slabs", n_row_blocks)
    _obs.metrics.inc("fused.chunk_steps", n_row_blocks * stats.n_chunks)
    return out, s_t_sum / 2.0 / n, stats


def fused_sw_design(xprep: Array, rows_fn: Callable, design, key: jax.Array,
                    n_total: int, *, row_block: int, chunk: int,
                    block_sparse: bool = True,
                    progress: Optional[Callable[[int, int], None]] = None):
    """The fused bridge for DENSE designs: per-column quadratic forms
    accumulated over mat2 row slabs, nothing (n, n)-shaped ever resident.
    Strata-blocked bases (the common multi-study / repeated-measures
    designs) contract block-sparsely: each column group only touches its
    strata's sample columns — exact, since the skipped terms are zeros.

    Returns (s_cols float64 ndarray (n_total, K), s_t float, FusedStats).
    """
    n = int(xprep.shape[0])
    k = design.k_cols
    basis = design.basis
    strata = (design.strata if design.strata is not None
              else jnp.zeros((n,), jnp.int32))
    groups = ()
    if block_sparse and design.strata is not None:
        groups = fstat.sparse_col_groups(basis, design.strata)
        if len(groups) <= 1:   # dense support: gather buys nothing
            groups = ()
    row_block = int(min(row_block, n))
    chunk = int(max(1, min(chunk, n_total)))
    out = np.zeros((n_total, k), np.float64)
    s_t_sum = 0.0
    n_row_blocks = 0
    for lo_r, slab in mat2_row_blocks(xprep, rows_fn, block=row_block):
        with _obs.span("fused.row_slab", {"lo": lo_r, "cols": k}):
            n_row_blocks += 1
            s_t_sum += float(jnp.sum(slab))
            for lo_p in range(0, n_total, chunk):
                sc = _fused_sw_step_cols(
                    slab, basis, strata, key, jnp.int32(lo_r),
                    jnp.int32(lo_p), chunk=chunk, block=slab.shape[0], n=n,
                    k_cols=k, groups=groups)
                hi = min(lo_p + chunk, n_total)
                out[lo_p:hi] += np.asarray(sc[: hi - lo_p], np.float64)
        if progress is not None:
            progress(min(lo_r + row_block, n), n)
    stats = FusedStats(
        n_total=n_total, chunk=chunk, n_chunks=-(-n_total // chunk),
        row_block=row_block, n_row_blocks=n_row_blocks,
        peak_slab_bytes=4 * row_block * n,
        peak_label_bytes=4 * chunk * n * (k + 1))
    _obs.metrics.inc("fused.row_slabs", n_row_blocks)
    _obs.metrics.inc("fused.chunk_steps", n_row_blocks * stats.n_chunks)
    return out, s_t_sum / 2.0 / n, stats


# ---------------------------------------------------------------------------
# Fused-kernel: single-pass distance → s_W (tentpole of the megakernel PR).
# ---------------------------------------------------------------------------

class FusedKernelStats(NamedTuple):
    """Execution evidence: how the single-pass sweep actually ran."""
    impl: str                # 'pallas' | 'xla'
    n_total: int
    chunk: int
    n_chunks: int
    row_block: int
    peak_slab_bytes: int     # (row_block, n) D² residency (0 for pallas:
                             # tiles never leave VMEM)
    peak_label_bytes: int    # (chunk, n) labels + (chunk, n, G) one-hot


def _sweep_rows_perms(x_rows_pad, x_full, grouping, inv_gs, key,
                      row_offset, perm_lo, *, rows_fn, block, chunk,
                      n_chunks, n, n_rows_pad, n_groups, strata=None):
    """Fully-traced fused sweep over LOCAL rows × a permutation range.

    x_rows_pad: (n_local, d) prepared features, n_local a multiple of
                `block`; the slab's global rows start at `row_offset`
                (traced — one program serves every shard/offset).
    perm_lo:    first global permutation index (traced); the sweep covers
                [perm_lo, perm_lo + n_chunks*chunk).
    strata:     None = free label permutations (the pre-design program);
                an (n,) array restricts draws within blocks.
    Returns (s_w (n_chunks*chunk,) f32 partial over these rows,
             row_sums (n_local,) f32). Scan over row blocks outside, scan
    over permutation chunks inside — each D² block is built once and
    consumed immediately; nothing (n, n)-shaped ever exists.
    """
    n_local = x_rows_pad.shape[0]
    d_feat = x_rows_pad.shape[1]
    chunk_los = perm_lo + jnp.arange(n_chunks) * chunk

    def slab_body(carry, lo_r):
        sw_acc, rs = carry
        xb = jax.lax.dynamic_slice(x_rows_pad, (lo_r, 0), (block, d_feat))
        drows = rows_fn(xb, x_full)                      # (block, n)
        gids = row_offset + lo_r + jnp.arange(block)
        valid = (gids < n)[:, None] & (gids[:, None]
                                       != jnp.arange(n)[None, :])
        m2 = jnp.where(valid, drows * drows, 0.0)

        def chunk_body(_, lo_p):
            if strata is None:
                g = permutations.permutation_batch_dyn(key, grouping, lo_p,
                                                       chunk)
            else:
                g = permutations.strata_label_batch_dyn(
                    key, grouping, strata, lo_p, chunk)
            e = fstat.onehot_perm_factors(g, inv_gs, m2.dtype)
            e_pad = jnp.pad(e, ((0, 0), (0, n_rows_pad - n), (0, 0)))
            e_rows = jax.lax.dynamic_slice(
                e_pad, (0, row_offset + lo_r, 0), (chunk, block, n_groups))
            return None, fstat.sw_matmul_contract(m2, e, e_rows)

        _, sws = jax.lax.scan(chunk_body, None, chunk_los)
        rs = jax.lax.dynamic_update_slice(rs, jnp.sum(m2, axis=1), (lo_r,))
        return (sw_acc + sws.reshape(-1), rs), None

    init = (jnp.zeros((n_chunks * chunk,), jnp.float32),
            jnp.zeros((n_local,), jnp.float32))
    (s_w, rs), _ = jax.lax.scan(slab_body, init,
                                jnp.arange(n_local // block) * block)
    return s_w, rs


def _sweep_rows_perms_design(x_rows_pad, x_full, basis, strata, key,
                             row_offset, perm_lo, *, rows_fn, block, chunk,
                             n_chunks, n, n_rows_pad, k_cols):
    """_sweep_rows_perms for DENSE designs: the chunk scan draws
    strata-restricted index permutations, gathers basis rows, and runs the
    per-column contraction. Returns (s_cols (n_chunks*chunk, K) f32,
    row_sums (n_local,) f32)."""
    n_local = x_rows_pad.shape[0]
    d_feat = x_rows_pad.shape[1]
    chunk_los = perm_lo + jnp.arange(n_chunks) * chunk

    def slab_body(carry, lo_r):
        sc_acc, rs = carry
        xb = jax.lax.dynamic_slice(x_rows_pad, (lo_r, 0), (block, d_feat))
        drows = rows_fn(xb, x_full)
        gids = row_offset + lo_r + jnp.arange(block)
        valid = (gids < n)[:, None] & (gids[:, None]
                                       != jnp.arange(n)[None, :])
        m2 = jnp.where(valid, drows * drows, 0.0)

        def chunk_body(_, lo_p):
            perms = permutations.strata_permutation_batch_dyn(
                key, strata, lo_p, chunk)
            v = fstat.basis_perm_factors(basis, perms)   # (chunk, n, K)
            v_pad = jnp.pad(v, ((0, 0), (0, n_rows_pad - n), (0, 0)))
            v_rows = jax.lax.dynamic_slice(
                v_pad, (0, row_offset + lo_r, 0), (chunk, block, k_cols))
            return None, fstat.sw_cols_contract(m2, v, v_rows)

        _, scs = jax.lax.scan(chunk_body, None, chunk_los)
        rs = jax.lax.dynamic_update_slice(rs, jnp.sum(m2, axis=1), (lo_r,))
        return (sc_acc + scs.reshape(-1, k_cols), rs), None

    init = (jnp.zeros((n_chunks * chunk, k_cols), jnp.float32),
            jnp.zeros((n_local,), jnp.float32))
    (s_cols, rs), _ = jax.lax.scan(slab_body, init,
                                   jnp.arange(n_local // block) * block)
    return s_cols, rs


@functools.partial(jax.jit, static_argnames=(
    "rows_fn", "block", "chunk", "n_chunks", "n", "n_rows_pad", "n_groups"))
def _onepass_step(x_rows_pad, x_full, grouping, strata, inv_gs, key, *,
                  rows_fn, block, chunk, n_chunks, n, n_rows_pad, n_groups):
    return _sweep_rows_perms(
        x_rows_pad, x_full, grouping, inv_gs, key, jnp.int32(0),
        jnp.int32(0), rows_fn=rows_fn, block=block, chunk=chunk,
        n_chunks=n_chunks, n=n, n_rows_pad=n_rows_pad, n_groups=n_groups,
        strata=strata)


@functools.partial(jax.jit, static_argnames=(
    "rows_fn", "block", "chunk", "n_chunks", "n", "n_rows_pad", "k_cols"))
def _onepass_step_design(x_rows_pad, x_full, basis, strata, key, *,
                         rows_fn, block, chunk, n_chunks, n, n_rows_pad,
                         k_cols):
    return _sweep_rows_perms_design(
        x_rows_pad, x_full, basis, strata, key, jnp.int32(0),
        jnp.int32(0), rows_fn=rows_fn, block=block, chunk=chunk,
        n_chunks=n_chunks, n=n, n_rows_pad=n_rows_pad, k_cols=k_cols)


def fused_sw_onepass(xprep: Array, rows_fn: Callable, grouping: Array,
                     inv_gs: Array, key: jax.Array, n_total: int, *,
                     row_block: int, chunk: int,
                     strata: Optional[Array] = None):
    """The fused sweep as ONE jitted program (the off-TPU megakernel form).

    Same math as `fused_sw`, but the (row block × perm chunk) double loop
    runs as a scan-of-scans inside a single dispatch: no per-cell host
    round trips, no host-side accumulation buffers, and XLA keeps each D²
    block live exactly as long as its contractions need it.
    """
    n = int(xprep.shape[0])
    n_groups = int(inv_gs.shape[0])
    block = int(min(row_block, n))
    chunk = int(max(1, min(chunk, n_total)))
    n_chunks = -(-n_total // chunk)
    xpad, n_pad = _pad_rows(xprep, block)
    s_w, rs = _onepass_step(
        xpad, xprep, jnp.asarray(grouping, jnp.int32), strata, inv_gs, key,
        rows_fn=rows_fn, block=block, chunk=chunk, n_chunks=n_chunks, n=n,
        n_rows_pad=n_pad, n_groups=n_groups)
    s_t = float(jnp.sum(rs)) / 2.0 / n
    _obs.metrics.inc("engine.perm_chunks", n_chunks)
    stats = FusedKernelStats(
        impl="xla", n_total=n_total, chunk=chunk, n_chunks=n_chunks,
        row_block=block, peak_slab_bytes=4 * block * n,
        peak_label_bytes=4 * chunk * n * (n_groups + 1))
    return np.asarray(s_w[:n_total], np.float64), s_t, stats


def fused_sw_onepass_design(xprep: Array, rows_fn: Callable, design,
                            key: jax.Array, n_total: int, *,
                            row_block: int, chunk: int):
    """fused_sw_onepass for DENSE designs: one jitted scan-of-scans, the
    per-column contraction inside. Returns (s_cols (n_total, K) f64,
    s_t, FusedKernelStats)."""
    n = int(xprep.shape[0])
    k = design.k_cols
    strata = (design.strata if design.strata is not None
              else jnp.zeros((n,), jnp.int32))
    block = int(min(row_block, n))
    chunk = int(max(1, min(chunk, n_total)))
    n_chunks = -(-n_total // chunk)
    xpad, n_pad = _pad_rows(xprep, block)
    s_cols, rs = _onepass_step_design(
        xpad, xprep, design.basis, strata, key, rows_fn=rows_fn,
        block=block, chunk=chunk, n_chunks=n_chunks, n=n, n_rows_pad=n_pad,
        k_cols=k)
    s_t = float(jnp.sum(rs)) / 2.0 / n
    _obs.metrics.inc("engine.perm_chunks", n_chunks)
    stats = FusedKernelStats(
        impl="xla", n_total=n_total, chunk=chunk, n_chunks=n_chunks,
        row_block=block, peak_slab_bytes=4 * block * n,
        peak_label_bytes=4 * chunk * n * (k + 1))
    return np.asarray(s_cols[:n_total], np.float64), s_t, stats


def _precision_roundtrip(xprep: Array, metric: str,
                         tuning: Optional[dict]) -> Array:
    """Value parity for the XLA one-pass path: quantize the feature table
    ONCE up front per the precision knobs, round-tripped back to f32 (XLA
    streams f32 regardless — the knobs buy traffic only on the kernel
    path), so both fused impls contract identical quantized features."""
    t = dict(tuning or {})
    if int(t.get("feat_packed", 0)):
        if metric != "jaccard":
            raise ValueError("feat_packed=1 requires the jaccard kernel "
                             f"body (got metric={metric!r})")
        return (jnp.asarray(xprep) > 0).astype(jnp.float32)
    if int(t.get("feat_fp8", 0)):
        return _dist.fp8_roundtrip(
            xprep, _dist.fp8_metric_scale(xprep, metric))
    if int(t.get("feat_bf16", 0)):
        return jnp.asarray(xprep, jnp.float32).astype(
            jnp.bfloat16).astype(jnp.float32)
    return xprep


def _fp8_scale_kwargs(xprep: Array, metric: str, tuning: dict) -> dict:
    """Per-metric fp8 calibration, computed ONCE per study before the chunk
    loop (re-deriving it per chunk would re-reduce the whole table)."""
    if int(tuning.get("feat_fp8", 0)):
        return {"feat_scale": _dist.fp8_metric_scale(xprep, metric)}
    return {}


_labels_step = jax.jit(permutations.permutation_batch_dyn,
                       static_argnames=("chunk", "identity_first"))
_strata_labels_step = jax.jit(permutations.strata_label_batch_dyn,
                              static_argnames=("chunk", "identity_first"))
_strata_perms_step = jax.jit(permutations.strata_permutation_batch_dyn,
                             static_argnames=("chunk", "identity_first"))


def fused_sw_megakernel(xprep: Array, grouping: Array, inv_gs: Array,
                        key: jax.Array, n_total: int, *, kernel_metric: str,
                        chunk: int, tuning: Optional[dict] = None,
                        interpret: Optional[bool] = None,
                        strata: Optional[Array] = None,
                        progress: Optional[Callable[[int, int], None]] = None):
    """The fused sweep through the Pallas megakernel (kernels.fused_sw).

    One kernel launch per permutation chunk covers ALL row/col tiles and
    every perm block of the chunk: D² tiles are built from feature slabs
    and contracted in VMEM, so the only HBM traffic per chunk is the
    feature table and the (chunk, n) labels — the distance matrix never
    exists at any scope wider than one (tile_r, tile_c) scratch buffer.
    Labels are generated outside the kernel, so strata-restricted draws
    slot straight in.
    """
    from repro.kernels.fused_sw import ops as _fops  # deferred: pallas
    n = int(xprep.shape[0])
    chunk = int(max(1, min(chunk, n_total)))
    tuning = dict(tuning or {})
    scale_kwargs = _fp8_scale_kwargs(xprep, kernel_metric, tuning)
    grouping = jnp.asarray(grouping, jnp.int32)
    out = np.zeros((n_total,), np.float64)
    rowsums = None
    n_chunks = 0
    for lo in range(0, n_total, chunk):
        with _obs.span("fusedk.chunk", {"lo": lo}):
            if strata is None:
                g = _labels_step(key, grouping, jnp.int32(lo), chunk=chunk)
            else:
                g = _strata_labels_step(key, grouping, strata, jnp.int32(lo),
                                        chunk=chunk)
            sw, rs = _fops.fused_sw_rows(
                xprep, xprep, g, g, inv_gs, 0, metric=kernel_metric,
                interpret=interpret, **scale_kwargs, **tuning)
            hi = min(lo + chunk, n_total)
            out[lo:hi] = np.asarray(sw[: hi - lo], np.float64)
        if rowsums is None:
            rowsums = np.asarray(rs, np.float64)
        n_chunks += 1
        if progress is not None:
            progress(hi, n_total)
    s_t = float(rowsums.sum()) / 2.0 / n
    tr = int(tuning.get("tile_r", 128))
    tc = int(tuning.get("tile_c", 128))
    stats = FusedKernelStats(
        impl="pallas", n_total=n_total, chunk=chunk, n_chunks=n_chunks,
        row_block=tr, peak_slab_bytes=16 * tr * tc,  # 4 VMEM scratch tiles
        peak_label_bytes=4 * chunk * n)
    _obs.metrics.inc("engine.perm_chunks", n_chunks)
    return out, s_t, stats


def fused_sw_megakernel_design(xprep: Array, design, key: jax.Array,
                               n_total: int, *, kernel_metric: str,
                               chunk: int, tuning: Optional[dict] = None,
                               interpret: Optional[bool] = None,
                               progress: Optional[Callable[[int, int],
                                                           None]] = None):
    """The megakernel sweep for DENSE designs: permuted basis blocks
    replace the in-kernel one-hot build (the MXU contraction consumes
    hat-matrix factor columns directly); per-column partials come back
    per chunk. D² residency is unchanged — VMEM tiles only."""
    from repro.kernels.fused_sw import ops as _fops  # deferred: pallas
    n = int(xprep.shape[0])
    k = design.k_cols
    basis = design.basis
    strata = (design.strata if design.strata is not None
              else jnp.zeros((n,), jnp.int32))
    chunk = int(max(1, min(chunk, n_total)))
    tuning = dict(tuning or {})
    scale_kwargs = _fp8_scale_kwargs(xprep, kernel_metric, tuning)
    out = np.zeros((n_total, k), np.float64)
    rowsums = None
    n_chunks = 0
    for lo in range(0, n_total, chunk):
        with _obs.span("fusedk.chunk", {"lo": lo, "cols": k}):
            perms = _strata_perms_step(key, strata, jnp.int32(lo),
                                       chunk=chunk)
            v = fstat.basis_perm_factors(basis, perms)
            sc, rs = _fops.fused_sw_rows_cols(
                xprep, xprep, v, v, 0, metric=kernel_metric,
                interpret=interpret, **scale_kwargs, **tuning)
            hi = min(lo + chunk, n_total)
            out[lo:hi] = np.asarray(sc[: hi - lo], np.float64)
        if rowsums is None:
            rowsums = np.asarray(rs, np.float64)
        n_chunks += 1
        if progress is not None:
            progress(hi, n_total)
    s_t = float(rowsums.sum()) / 2.0 / n
    tr = int(tuning.get("tile_r", 128))
    tc = int(tuning.get("tile_c", 128))
    stats = FusedKernelStats(
        impl="pallas", n_total=n_total, chunk=chunk, n_chunks=n_chunks,
        row_block=tr, peak_slab_bytes=16 * tr * tc,
        peak_label_bytes=4 * chunk * n * (k + 1))
    _obs.metrics.inc("engine.perm_chunks", n_chunks)
    return out, s_t, stats


def fused_kernel_sw(xprep: Array, rows_fn: Callable, grouping: Array,
                    inv_gs: Array, key: jax.Array, n_total: int, *,
                    impl: str, kernel_metric: str, row_block: int,
                    chunk: int, tuning: Optional[dict] = None,
                    interpret: Optional[bool] = None,
                    strata: Optional[Array] = None,
                    progress: Optional[Callable[[int, int], None]] = None):
    """Dispatch the single-pass fused sweep to the planned implementation.

    impl: 'pallas' (the megakernel; interpret mode off TPU) or 'xla' (the
    one-jit scan-of-scans). Both return (s_w (n_total,) float64, s_t,
    FusedKernelStats) with identical statistics for a fixed key.
    """
    if impl == "pallas":
        return fused_sw_megakernel(
            xprep, grouping, inv_gs, key, n_total,
            kernel_metric=kernel_metric, chunk=chunk, tuning=tuning,
            interpret=interpret, strata=strata, progress=progress)
    if impl == "xla":
        return fused_sw_onepass(
            _precision_roundtrip(xprep, kernel_metric, tuning), rows_fn,
            grouping, inv_gs, key, n_total,
            row_block=row_block, chunk=chunk, strata=strata)
    raise ValueError(f"unknown fused-kernel impl {impl!r}; "
                     "expected 'pallas' or 'xla'")


def fused_kernel_sw_design(xprep: Array, rows_fn: Callable, design,
                           key: jax.Array, n_total: int, *,
                           impl: str, kernel_metric: str, row_block: int,
                           chunk: int, tuning: Optional[dict] = None,
                           interpret: Optional[bool] = None):
    """fused_kernel_sw for DENSE designs: both impls return
    (s_cols (n_total, K) float64, s_t, FusedKernelStats)."""
    if impl == "pallas":
        return fused_sw_megakernel_design(
            xprep, design, key, n_total, kernel_metric=kernel_metric,
            chunk=chunk, tuning=tuning, interpret=interpret)
    if impl == "xla":
        return fused_sw_onepass_design(
            _precision_roundtrip(xprep, kernel_metric, tuning), rows_fn,
            design, key, n_total, row_block=row_block, chunk=chunk)
    raise ValueError(f"unknown fused-kernel impl {impl!r}; "
                     "expected 'pallas' or 'xla'")


# ---------------------------------------------------------------------------
# Out-of-core fused sweeps: the feature table never exists in memory. Disk
# slabs arrive through the async prefetcher (slab k+1 staged while slab k's
# tiles contract), each (slab_rows, n) m2 row slab is assembled from
# (slab, slab) distance tiles, and the UNCHANGED fused steps consume it —
# so the statistic is bit-identical to the in-memory bridges at the same
# slab boundaries by construction.
# ---------------------------------------------------------------------------

class OocStats(NamedTuple):
    """Execution evidence: how the out-of-core sweep actually ran."""
    n_total: int
    chunk: int
    n_chunks: int
    slab_rows: int
    n_slabs: int
    disk_bytes_read: int     # actual bytes through the prefetcher
    stall_s: float           # consumer time blocked on slab I/O
    sweep_s: float           # whole-sweep wall clock


@functools.partial(jax.jit,
                   static_argnames=("rows_fn", "prep_fn", "block", "n"))
def _ooc_m2_tile(x_rows, x_cols, lo_r, lo_c, *, rows_fn, prep_fn, block, n):
    """One (block, block) m2 tile from two RAW feature slabs: metric prep
    (row-local for every registered metric) then distance rows, squared,
    with pad rows/cols and the exact diagonal zeroed by GLOBAL ids.
    lo_r/lo_c are traced, so one compiled program serves the whole sweep
    — zero warm retraces regardless of slab count."""
    drows = rows_fn(prep_fn(x_rows), prep_fn(x_cols))
    gi = lo_r + jnp.arange(block)
    gj = lo_c + jnp.arange(block)
    valid = (gi < n)[:, None] & (gj < n)[None, :] \
        & (gi[:, None] != gj[None, :])
    return jnp.where(valid, drows * drows, 0.0)


@functools.partial(jax.jit, static_argnames=("chunk", "n_chunks", "block",
                                             "n", "n_groups"))
def _ooc_rowslab_onepass(m2rows, grouping, strata, inv_gs, key, lo_r, *,
                         chunk, n_chunks, block, n, n_groups):
    """ONE dispatch covering every permutation chunk of one assembled m2
    row slab (the fused-kernel form out of core: scan inside, so the slab
    is read from HBM once per chunk without per-chunk host syncs)."""
    chunk_los = jnp.arange(n_chunks) * chunk

    def chunk_body(_, lo_p):
        if strata is None:
            g = permutations.permutation_batch_dyn(key, grouping, lo_p,
                                                   chunk)
        else:
            g = permutations.strata_label_batch_dyn(key, grouping, strata,
                                                    lo_p, chunk)
        e = fstat.onehot_perm_factors(g, inv_gs, m2rows.dtype)
        e_pad = jnp.pad(e, ((0, 0), (0, (-n) % block), (0, 0)))
        e_rows = jax.lax.dynamic_slice(e_pad, (0, lo_r, 0),
                                       (chunk, block, n_groups))
        return None, fstat.sw_matmul_contract(m2rows, e, e_rows)

    _, sws = jax.lax.scan(chunk_body, None, chunk_los)
    return sws.reshape(-1)


@functools.partial(jax.jit, static_argnames=("chunk", "n_chunks", "block",
                                             "n", "k_cols", "groups"))
def _ooc_rowslab_onepass_cols(m2rows, basis, strata, key, lo_r, *,
                              chunk, n_chunks, block, n, k_cols, groups=()):
    """_ooc_rowslab_onepass for DENSE designs (per-column contraction)."""
    chunk_los = jnp.arange(n_chunks) * chunk

    def chunk_body(_, lo_p):
        perms = permutations.strata_permutation_batch_dyn(key, strata, lo_p,
                                                          chunk)
        v = fstat.basis_perm_factors(basis, perms)
        v_pad = jnp.pad(v, ((0, 0), (0, (-n) % block), (0, 0)))
        v_rows = jax.lax.dynamic_slice(v_pad, (0, lo_r, 0),
                                       (chunk, block, k_cols))
        if groups:
            return None, fstat.sw_cols_contract_sparse(m2rows, v, v_rows,
                                                       groups)
        return None, fstat.sw_cols_contract(m2rows, v, v_rows)

    _, scs = jax.lax.scan(chunk_body, None, chunk_los)
    return scs.reshape(-1, k_cols)


def _ooc_sweep(cache, rows_fn, prep_fn, consume, *, prefetch_depth=2):
    """Drive one full OOC pass: for each row slab r, prefetch slab r then
    the whole column stream, assemble the (slab_rows, n) m2 row slab from
    tiles, and hand it to `consume(lo_r, m2rows)`. The prefetcher thread
    is torn down even when consume raises mid-sweep. Returns the drained
    prefetcher (for its I/O counters)."""
    from repro.data import slabcache as _slabcache
    n, block, n_slabs = cache.n, cache.slab_rows, cache.n_slabs
    pf = _slabcache.SlabPrefetcher(cache, _slabcache.ooc_schedule(n_slabs),
                                   depth=prefetch_depth, pad_to=block)
    try:
        it = iter(pf)
        for r in range(n_slabs):
            _, x_rows = next(it)
            lo_r = r * block
            with _obs.span("ooc.row_slab", {"lo": lo_r}):
                tiles = []
                for c in range(n_slabs):
                    _, x_cols = next(it)
                    tiles.append(_ooc_m2_tile(
                        x_rows, x_cols, jnp.int32(lo_r),
                        jnp.int32(c * block), rows_fn=rows_fn,
                        prep_fn=prep_fn, block=block, n=n))
                m2 = jnp.concatenate(tiles, axis=1)[:, :n]
                consume(lo_r, m2)
    finally:
        pf.close()
    return pf


def fused_sw_ooc(cache, rows_fn: Callable, prep_fn: Callable,
                 grouping: Array, inv_gs: Array, key: jax.Array,
                 n_total: int, *, chunk: int,
                 strata: Optional[Array] = None, onepass: bool = False,
                 prefetch_depth: int = 2):
    """s_W with the feature table on DISK: slab-cache streaming into the
    fused contraction. onepass=False reuses `_fused_sw_step` verbatim (the
    'fused' bridge out of core — bit-identical partial sums in the same
    accumulation order as `fused_sw` at row_block == slab_rows);
    onepass=True runs one dispatch per row slab (the 'fused-kernel' form).

    Returns (s_w float64 (n_total,), s_t float, OocStats).
    """
    n = cache.n
    block = cache.slab_rows
    n_groups = int(inv_gs.shape[0])
    chunk = int(max(1, min(chunk, n_total)))
    n_chunks = -(-n_total // chunk)
    grouping = jnp.asarray(grouping, jnp.int32)
    out = np.zeros((n_total,), np.float64)
    s_t_sum = 0.0

    def consume(lo_r, m2):
        nonlocal s_t_sum
        s_t_sum += float(jnp.sum(m2))
        if onepass:
            sws = _ooc_rowslab_onepass(
                m2, grouping, strata, inv_gs, key, jnp.int32(lo_r),
                chunk=chunk, n_chunks=n_chunks, block=block, n=n,
                n_groups=n_groups)
            out[:] += np.asarray(sws[:n_total], np.float64)
        else:
            for lo_p in range(0, n_total, chunk):
                sw = _fused_sw_step(
                    m2, grouping, strata, inv_gs, key, jnp.int32(lo_r),
                    jnp.int32(lo_p), chunk=chunk, block=block, n=n,
                    n_groups=n_groups)
                hi = min(lo_p + chunk, n_total)
                out[lo_p:hi] += np.asarray(sw[: hi - lo_p], np.float64)

    t0 = time.perf_counter()
    pf = _ooc_sweep(cache, rows_fn, prep_fn, consume,
                    prefetch_depth=prefetch_depth)
    sweep_s = time.perf_counter() - t0
    _obs.metrics.inc("fused.row_slabs", cache.n_slabs)
    _obs.metrics.inc("fused.chunk_steps", cache.n_slabs * n_chunks)
    stats = OocStats(
        n_total=n_total, chunk=chunk, n_chunks=n_chunks, slab_rows=block,
        n_slabs=cache.n_slabs, disk_bytes_read=pf.bytes_read,
        stall_s=pf.stall_s, sweep_s=sweep_s)
    return out, s_t_sum / 2.0 / n, stats


def fused_sw_ooc_design(cache, rows_fn: Callable, prep_fn: Callable,
                        design, key: jax.Array, n_total: int, *,
                        chunk: int, block_sparse: bool = True,
                        onepass: bool = False, prefetch_depth: int = 2):
    """fused_sw_ooc for DENSE designs (covariates / strata / weights):
    the per-column contraction over disk-streamed m2 row slabs. Returns
    (s_cols float64 (n_total, K), s_t float, OocStats)."""
    n = cache.n
    block = cache.slab_rows
    k = design.k_cols
    basis = design.basis
    strata = (design.strata if design.strata is not None
              else jnp.zeros((n,), jnp.int32))
    groups = ()
    if block_sparse and design.strata is not None:
        groups = fstat.sparse_col_groups(basis, design.strata)
        if len(groups) <= 1:
            groups = ()
    chunk = int(max(1, min(chunk, n_total)))
    n_chunks = -(-n_total // chunk)
    out = np.zeros((n_total, k), np.float64)
    s_t_sum = 0.0

    def consume(lo_r, m2):
        nonlocal s_t_sum
        s_t_sum += float(jnp.sum(m2))
        if onepass:
            scs = _ooc_rowslab_onepass_cols(
                m2, basis, strata, key, jnp.int32(lo_r), chunk=chunk,
                n_chunks=n_chunks, block=block, n=n, k_cols=k,
                groups=groups)
            out[:] += np.asarray(scs[:n_total], np.float64)
        else:
            for lo_p in range(0, n_total, chunk):
                sc = _fused_sw_step_cols(
                    m2, basis, strata, key, jnp.int32(lo_r),
                    jnp.int32(lo_p), chunk=chunk, block=block, n=n,
                    k_cols=k, groups=groups)
                hi = min(lo_p + chunk, n_total)
                out[lo_p:hi] += np.asarray(sc[: hi - lo_p], np.float64)

    t0 = time.perf_counter()
    pf = _ooc_sweep(cache, rows_fn, prep_fn, consume,
                    prefetch_depth=prefetch_depth)
    sweep_s = time.perf_counter() - t0
    _obs.metrics.inc("fused.row_slabs", cache.n_slabs)
    _obs.metrics.inc("fused.chunk_steps", cache.n_slabs * n_chunks)
    stats = OocStats(
        n_total=n_total, chunk=chunk, n_chunks=n_chunks, slab_rows=block,
        n_slabs=cache.n_slabs, disk_bytes_read=pf.bytes_read,
        stall_s=pf.stall_s, sweep_s=sweep_s)
    return out, s_t_sum / 2.0 / n, stats


# ---------------------------------------------------------------------------
# Multi-device fused sharding: row slabs over 'model', perms over the rest.
# ---------------------------------------------------------------------------

def fused_sw_sharded(mesh, xprep: Array, rows_fn: Callable, grouping: Array,
                     inv_gs: Array, key: jax.Array, n_total: int, *,
                     row_block: int, chunk: int):
    """The fused sweep over a (…, 'data', 'model') device mesh.

    Mirrors core.distributed's mapping without ever building the matrix:
    'model' shards the feature-table ROWS (each device sweeps only its row
    slab's D² blocks — peak per-device residency (row_block, n)), the
    remaining axes shard the PERMUTATION range (labels regenerated
    shard-locally by global-index key folding). One psum over 'model'
    reconstructs each permutation's statistic exactly.

    The host drives one shard_map dispatch per permutation WINDOW of
    perm_ways * chunk global indices; inside it each shard generates its
    (chunk, n) label block with a single key-folding call. (Folding inside
    a lax.scan over traced chunk offsets miscompiles under shard_map on
    jax 0.4.x — the folded offsets silently collapse to the first shard's
    when the labels feed a matmul — so the chunk loop stays at the host,
    exactly like the megakernel driver.)

    Returns (s_w (n_total,) float64, s_t float, FusedKernelStats).
    """
    from repro.core import distributed as _distrib  # deferred: jax mesh
    n, d_feat = (int(s) for s in xprep.shape)
    n_groups = int(inv_gs.shape[0])
    model_ways = mesh.shape["model"]
    perm_axes = _distrib._perm_axes(mesh)
    perm_ways = 1
    for a in perm_axes:
        perm_ways *= mesh.shape[a]

    rows_per_shard = -(-n // model_ways)
    block = int(min(row_block, rows_per_shard))
    rows_per_shard = -(-rows_per_shard // block) * block
    n_rows_pad = rows_per_shard * model_ways
    xpad = jnp.pad(xprep, ((0, n_rows_pad - n), (0, 0)))

    chunk_local = int(max(1, min(chunk, -(-n_total // perm_ways))))
    window = chunk_local * perm_ways
    grouping = jnp.asarray(grouping, jnp.int32)

    def body(x_rows, x_full, grp, igs, k, wlo):
        row_offset = jax.lax.axis_index("model") * rows_per_shard
        pidx = jnp.zeros((), jnp.int32)
        for a in perm_axes:  # row-major linearization over perm axes
            pidx = pidx * mesh.shape[a] + jax.lax.axis_index(a)
        lo = wlo[0] + pidx * chunk_local
        g = permutations.permutation_batch_dyn(k, grp, lo, chunk_local)
        e = fstat.onehot_perm_factors(g, igs, jnp.float32)
        e_pad = jnp.pad(e, ((0, 0), (0, n_rows_pad - n), (0, 0)))

        def slab_body(carry, lo_r):
            sw_acc, rs = carry
            xb = jax.lax.dynamic_slice(x_rows, (lo_r, 0), (block, d_feat))
            drows = rows_fn(xb, x_full)
            gids = row_offset + lo_r + jnp.arange(block)
            valid = (gids < n)[:, None] & (gids[:, None]
                                           != jnp.arange(n)[None, :])
            m2 = jnp.where(valid, drows * drows, 0.0)
            e_rows = jax.lax.dynamic_slice(
                e_pad, (0, row_offset + lo_r, 0),
                (chunk_local, block, n_groups))
            rs = jax.lax.dynamic_update_slice(rs, jnp.sum(m2, axis=1),
                                              (lo_r,))
            return (sw_acc + fstat.sw_matmul_contract(m2, e, e_rows),
                    rs), None

        init = (jnp.zeros((chunk_local,), jnp.float32),
                jnp.zeros((rows_per_shard,), jnp.float32))
        (s_w, rs), _ = jax.lax.scan(
            slab_body, init, jnp.arange(rows_per_shard // block) * block)
        return jax.lax.psum(s_w, axis_name="model"), rs

    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P("model", None), P(), P(), P(), P(), P()),
        out_specs=(P(perm_axes), P("model")))
    out = np.zeros((n_total,), np.float64)
    rowsums = None
    n_windows = 0
    for wlo in range(0, n_total, window):
        with _obs.span("fusedk.window", {"lo": wlo}):
            s_w, rs = fn(xpad, xprep, grouping, inv_gs, key,
                         jnp.full((1,), wlo, jnp.int32))
            hi = min(wlo + window, n_total)
            out[wlo:hi] = np.asarray(s_w[: hi - wlo], np.float64)
        if rowsums is None:
            rowsums = np.asarray(rs, np.float64)
        n_windows += 1
    s_t = float(rowsums[:n].sum()) / 2.0 / n
    stats = FusedKernelStats(
        impl="xla", n_total=n_total, chunk=chunk_local,
        n_chunks=n_windows * perm_ways, row_block=block,
        peak_slab_bytes=4 * block * n,
        peak_label_bytes=4 * chunk_local * n * (n_groups + 1))
    _obs.metrics.inc("engine.perm_chunks", stats.n_chunks)
    return out, s_t, stats
