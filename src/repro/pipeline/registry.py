"""Unified distance-implementation registry (stage 1 of the pipeline).

Mirrors `repro.engine.registry` for the distance stage: every way this repo
can turn an (n, d) abundance table into pairwise distances sits behind one
interface with capability metadata the pipeline planner dispatches on.

Three kinds per metric (where available):

  dense     single full-matrix jnp form (Gram trick / broadcast) — lowest
            latency while the O(n^2)..O(block*n*d) transients fit
  blocked   row-streaming jnp driver over the same row primitives — the
            cache-friendly CPU form, and the only dense-free producer for
            the pipeline's stream/fused materializations
  pallas    the tiled TPU kernels (interpret mode off TPU) — rectangular,
            so they serve both dense construction and row slabs

Every impl exposes BOTH a dense builder and a row-slab builder (the dense
matrix is just the all-rows slab), so the planner's materialization choice
(dense / stream / fused) is orthogonal to the impl choice — exactly like
the s_W registry keeps dataflow orthogonal to scheduling.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Mapping, Optional, Tuple

import jax.numpy as jnp

from repro.core import distance as _dist

Array = object


# ---------------------------------------------------------------------------
# Residency tiers: one bandwidth model for every level features can live at.
# The planner's working-set arithmetic is the single source of truth from
# VMEM down to disk — the MI300A unified-memory residency argument extended
# one tier below HBM (out-of-core slab streaming).
# ---------------------------------------------------------------------------

RESIDENCY_TIERS = ("vmem", "hbm", "host", "disk")

# Model bandwidths (B/s). vmem: TPU-class on-chip SRAM order of magnitude;
# hbm resolves per backend from the paper's measured numbers; host: DDR-class
# staging the prefetcher reads through; disk: NVMe-class sequential read.
# $REPRO_TIER_GBPS_<TIER> overrides any of them (GB/s).
_VMEM_BPS = 22e12
_HOST_BPS = 64e9
_DISK_BPS = 2e9


def tier_bandwidth_gbps(tier: str, backend: Optional[str] = None) -> float:
    """Modelled bandwidth of one residency tier in GB/s ('hbm' is the
    backend's device-memory roof: the paper's STREAM-triad numbers on
    MI300A families, the v5e HBM roof on TPU)."""
    if tier not in RESIDENCY_TIERS:
        raise ValueError(f"unknown residency tier {tier!r}; "
                         f"one of {RESIDENCY_TIERS}")
    override = os.environ.get(f"REPRO_TIER_GBPS_{tier.upper()}")
    if override:
        return float(override)
    if tier == "vmem":
        return _VMEM_BPS / 1e9
    if tier == "host":
        return _HOST_BPS / 1e9
    if tier == "disk":
        return _DISK_BPS / 1e9
    from repro import hw
    if backend is None:
        import jax
        backend = jax.default_backend()
    if backend == "tpu":
        return hw.TPU_V5E.hbm_bandwidth / 1e9
    if backend == "gpu":
        return hw.MI300A_GPU_STREAM_TRIAD / 1e9
    return hw.MI300A_CPU_STREAM_TRIAD / 1e9


def residency_tier(feature_bytes: float, *, device_budget_bytes: float,
                   host_budget_bytes: float) -> str:
    """Where the feature table LIVES during the sweep: 'hbm' while its f32
    form fits the device budget (stream the cache once, then run the
    in-memory bridges), 'host'/'disk' otherwise (out-of-core slab
    streaming; the tiers differ only in the bandwidth the traffic model
    charges — page-cache-warm vs cold reads)."""
    if feature_bytes <= device_budget_bytes:
        return "hbm"
    if feature_bytes <= host_budget_bytes:
        return "host"
    return "disk"


def ooc_disk_traffic_bytes(n_slabs: int, disk_bytes: float) -> float:
    """Modelled bytes read from the slab cache for ONE full OOC sweep: per
    row slab, the row operand plus the entire column stream — (n_slabs+1)
    passes over the on-disk table. Independent of n_perms: every
    permutation chunk consumes the LIVE assembled row slab, so the
    permutation axis adds no disk traffic (that is the whole point of
    fusing the sweep into the stream)."""
    return float(disk_bytes) * (int(n_slabs) + 1)


@dataclasses.dataclass(frozen=True)
class DistanceImpl:
    """One distance implementation plus planner-facing metadata.

    make_prepare(**tuning) -> prepare(x) -> xprep        one-off transform
    make_rows(**tuning)    -> rows(xb, xprep) -> (b, n)  row-slab builder
    make_dense(**tuning)   -> dense(x) -> (n, n)         full matrix
    """
    name: str                      # "<metric>.<kind>"
    metric: str
    kind: str                      # 'dense' | 'blocked' | 'pallas'
    backends: Tuple[str, ...]      # backends where this form is performant
    tuning: Mapping[str, int]
    make_prepare: Callable[..., Callable]
    make_rows: Callable[..., Callable]
    make_dense: Callable[..., Callable]
    workset_bytes: Callable[[int, int, int], int]
    # (n, d, row_block) -> peak TRANSIENT bytes beyond inputs/outputs
    max_n: Optional[int] = None    # None = unbounded
    description: str = ""

    def bound(self, **overrides):
        """(prepare, rows, dense) callables with tuning resolved."""
        kw = {k: v for k, v in {**self.tuning, **overrides}.items()
              if k in self.tuning}
        key = (self.name, tuple(sorted(kw.items())))
        fns = _BOUND_CACHE.get(key)
        if fns is None:
            fns = _BOUND_CACHE[key] = (self.make_prepare(**kw),
                                       self.make_rows(**kw),
                                       self.make_dense(**kw))
        return fns


_REGISTRY: dict = {}
_BOUND_CACHE: dict = {}


def register(impl: DistanceImpl) -> DistanceImpl:
    if impl.name in _REGISTRY:
        raise ValueError(f"duplicate distance impl {impl.name!r}")
    _REGISTRY[impl.name] = impl
    return impl


def get(name: str) -> DistanceImpl:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown distance impl {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names(*, metric: Optional[str] = None, backend: Optional[str] = None,
          kind: Optional[str] = None):
    """Registered impl names, optionally filtered by capability."""
    out = []
    for n, impl in _REGISTRY.items():
        if metric is not None and impl.metric != metric:
            continue
        if backend is not None and backend not in impl.backends:
            continue
        if kind is not None and impl.kind != kind:
            continue
        out.append(n)
    return sorted(out)


def metrics():
    return sorted({impl.metric for impl in _REGISTRY.values()})


# ---------------------------------------------------------------------------
# Registration.
# ---------------------------------------------------------------------------

def _const(fn):
    def make(**_tuning):
        return fn
    return make


def _make_true_dense(metric):
    """Single-shot full-matrix form: all rows against all rows in one call
    (the GPU-brute analogue — maximum parallel width, O(n*n[*d]) transients
    exactly as the workset model charges)."""
    mdef = _dist.ROW_METRICS[metric]

    def make(**_tuning):
        def dense(x):
            xp = mdef.prepare(x)
            return _dist._zero_diag(mdef.rows(xp, xp))
        return dense
    return make


def _make_dense_from_rows(metric):
    mdef = _dist.ROW_METRICS[metric]

    def make(**tuning):
        block = tuning.get("block", 256)

        def dense(x):
            xp = mdef.prepare(x)
            return _dist._zero_diag(
                _dist._blocked_rows(mdef.rows, xp, block))
        return dense
    return make


def _make_pallas_rows(metric):
    kmetric = "euclidean" if metric == "aitchison" else metric

    def make(**tuning):
        from repro.kernels.distance import ops  # deferred: pallas import

        def rows(xb, xprep):
            return ops.pairwise_distance_rows(xb, xprep, metric=kmetric,
                                              **tuning)
        return rows
    return make


def _make_pallas_dense(metric):
    def make(**tuning):
        from repro.kernels.distance import ops  # deferred: pallas import
        prep = _dist.ROW_METRICS[metric].prepare
        kmetric = "euclidean" if metric == "aitchison" else metric

        def dense(x):
            return ops.pairwise_distance(prep(x), metric=kmetric, **tuning)
        return dense
    return make


def _ws_dense_gram(n, d, _block):
    # full Gram product + squared-distance intermediate
    return 8 * n * n


def _ws_dense_broadcast(n, d, block):
    # (block, n, d) broadcast intermediates inside the scan body (x2: |.|, +)
    return 8 * block * n * d


def _ws_rows_gram(n, d, block):
    return 8 * block * n


def _ws_rows_broadcast(n, d, block):
    return 8 * block * n * d


def _ws_pallas(n, d, block):
    # accumulators materialized at output size (interpret mode); tiles on TPU
    return 12 * min(block, n) * n


def _register_metric(metric, *, rows_ws, dense_ws, pallas_ok,
                     dense_backends, blocked_backends):
    mdef = _dist.ROW_METRICS[metric]
    register(DistanceImpl(
        name=f"{metric}.dense", metric=metric, kind="dense",
        backends=dense_backends, tuning={},
        make_prepare=_const(mdef.prepare), make_rows=_const(mdef.rows),
        make_dense=_make_true_dense(metric),
        workset_bytes=dense_ws,
        description=f"single full-matrix jnp {metric} (GPU-brute analogue: "
                    "maximum parallel width, largest transients)",
    ))
    register(DistanceImpl(
        name=f"{metric}.blocked", metric=metric, kind="blocked",
        backends=blocked_backends, tuning={"block": 256},
        make_prepare=_const(mdef.prepare), make_rows=_const(mdef.rows),
        make_dense=_make_dense_from_rows(metric),
        workset_bytes=rows_ws,
        description=f"row-streaming jnp {metric} (CPU-tiled analogue: "
                    "bounded working set; feeds stream/fused plans)",
    ))
    if pallas_ok:
        register(DistanceImpl(
            name=f"{metric}.pallas", metric=metric, kind="pallas",
            backends=("tpu",),
            tuning={"tile_r": 128, "tile_c": 128, "feat_block": 128},
            make_prepare=_const(mdef.prepare),
            make_rows=_make_pallas_rows(metric),
            make_dense=_make_pallas_dense(metric),
            workset_bytes=_ws_pallas,
            description=f"Pallas tiled {metric} kernel (VMEM-resident "
                        "accumulators; interpret mode off TPU)",
        ))


# euclidean / aitchison: Gram-trick forms are BLAS/MXU-native everywhere.
_register_metric("euclidean", rows_ws=_ws_rows_gram, dense_ws=_ws_dense_gram,
                 pallas_ok=True, dense_backends=("cpu", "gpu", "tpu"),
                 blocked_backends=("cpu", "gpu", "tpu"))
_register_metric("aitchison", rows_ws=_ws_rows_gram, dense_ws=_ws_dense_gram,
                 pallas_ok=True, dense_backends=("cpu", "gpu", "tpu"),
                 blocked_backends=("cpu", "gpu", "tpu"))
# braycurtis: broadcast form has (block, n, d) transients — blocked is the
# CPU winner, dense the GPU one (paper Fig. 1 transplanted to stage 1).
_register_metric("braycurtis", rows_ws=_ws_rows_broadcast,
                 dense_ws=_ws_dense_broadcast, pallas_ok=True,
                 dense_backends=("gpu",), blocked_backends=("cpu", "gpu"))
# jaccard: presence/absence matmul form — the Pallas tile accumulates
# |A ∩ B| on the MXU, so every registered metric now has a tiled impl.
_register_metric("jaccard", rows_ws=_ws_rows_gram, dense_ws=_ws_dense_gram,
                 pallas_ok=True, dense_backends=("cpu", "gpu", "tpu"),
                 blocked_backends=("cpu", "gpu", "tpu"))
# packed=1 switches jaccard.pallas to uint32 presence words + popcount
# tiles (bit-identical distances, 32x fewer feature bytes)
_REGISTRY["jaccard.pallas"] = dataclasses.replace(
    _REGISTRY["jaccard.pallas"],
    tuning={**_REGISTRY["jaccard.pallas"].tuning, "packed": 0})


# ---------------------------------------------------------------------------
# Precision knobs shared by the fused megakernel and the traffic models.
# ---------------------------------------------------------------------------

PRECISIONS = ("f32", "bf16", "fp8", "packed")


def precision_tag(tuning) -> str:
    """Canonical precision tag of a fused tuning dict (cache-key /
    reporting vocabulary; packed > fp8 > bf16 > f32)."""
    t = tuning or {}
    if t.get("feat_packed"):
        return "packed"
    if t.get("feat_fp8"):
        return "fp8"
    if t.get("feat_bf16"):
        return "bf16"
    return "f32"


def precision_tuning(tag: str) -> dict:
    """The fused tuning-knob dict selecting a precision tag."""
    if tag not in PRECISIONS:
        raise ValueError(f"unknown precision {tag!r}; one of {PRECISIONS}")
    return {"feat_bf16": int(tag == "bf16"), "feat_fp8": int(tag == "fp8"),
            "feat_packed": int(tag == "packed")}


def feat_element_bytes(tuning) -> float:
    """Bytes moved per FEATURE element at the tuning dict's precision
    (packed: 32 presence bits per uint32 word = 1/8 byte each)."""
    return {"f32": 4.0, "bf16": 2.0, "fp8": 1.0,
            "packed": 0.125}[precision_tag(tuning)]


# ---------------------------------------------------------------------------
# Fused-kernel (single-pass distance→s_W) implementation registry.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedImpl:
    """One single-pass distance→s_W implementation (the fused-kernel
    materialization bridge) plus planner-facing metadata.

    Unlike DistanceImpl, a fused impl produces no distance operand at all:
    it executes the whole features→s_W sweep (pipeline.streaming's
    `fused_kernel_sw` dispatches on `kind`). `workset_bytes` models the
    peak DEVICE residency beyond the (n, d) features and (chunk, n)
    labels as a function of (n, d, chunk, n_groups, row_block) — for the
    Pallas megakernel that is a handful of VMEM tiles, independent of n.
    """
    name: str                      # "<metric>.fusedk.<kind>"
    metric: str
    kind: str                      # 'pallas' | 'xla'
    backends: Tuple[str, ...]      # backends where this form is performant
    tuning: Mapping[str, int]
    workset_bytes: Callable[[int, int, int, int, int], int]
    kernel_metric: str             # megakernel body (aitchison→euclidean)
    description: str = ""


_FUSED_REGISTRY: dict = {}


def register_fused(impl: FusedImpl) -> FusedImpl:
    if impl.name in _FUSED_REGISTRY:
        raise ValueError(f"duplicate fused impl {impl.name!r}")
    _FUSED_REGISTRY[impl.name] = impl
    return impl


def get_fused(name: str) -> FusedImpl:
    try:
        return _FUSED_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown fused impl {name!r}; "
            f"registered: {sorted(_FUSED_REGISTRY)}") from None


def fused_names(*, metric: Optional[str] = None,
                backend: Optional[str] = None,
                kind: Optional[str] = None):
    """Registered fused-kernel impl names, filtered by capability."""
    out = []
    for n, impl in _FUSED_REGISTRY.items():
        if metric is not None and impl.metric != metric:
            continue
        if backend is not None and backend not in impl.backends:
            continue
        if kind is not None and impl.kind != kind:
            continue
        out.append(n)
    return sorted(out)


def _ws_fused_pallas(n, d, chunk, n_groups, row_block):
    # 4 VMEM scratch tiles + the (chunk,) accumulator — independent of n²
    tr = tc = 128
    return 16 * tr * tc + 4 * chunk


def _ws_fused_xla(n, d, chunk, n_groups, row_block):
    # one (row_block, n) D² slab + the (chunk, n, G) one-hot factor
    return 4 * row_block * n + 4 * chunk * n * (n_groups + 1)


for _metric in ("euclidean", "aitchison", "braycurtis", "jaccard"):
    _kmetric = "euclidean" if _metric == "aitchison" else _metric
    # The precision-knob family (mutually exclusive; planner/autotune
    # values land in the persisted cache entry's tuning dict alongside
    # tile sizes): feat_bf16 halves HBM feature traffic, feat_fp8
    # quarters it (per-study scale calibration, fp32 accumulation),
    # feat_packed (jaccard only) cuts it 32x via uint32 presence words
    # with bit-identical results.
    _prec = {"feat_bf16": 0, "feat_fp8": 0}
    if _kmetric == "jaccard":
        _prec["feat_packed"] = 0
    register_fused(FusedImpl(
        name=f"{_metric}.fusedk.pallas", metric=_metric, kind="pallas",
        backends=("tpu",),
        tuning={"tile_r": 128, "tile_c": 128, "feat_block": 128,
                "perm_block": 16, **_prec},
        workset_bytes=_ws_fused_pallas, kernel_metric=_kmetric,
        description=f"Pallas megakernel: {_metric} D² tiles built and "
                    "contracted in VMEM; D² never touches HBM "
                    "(feat_bf16/feat_fp8/feat_packed shrink feature-slab "
                    "traffic 2x/4x/32x)",
    ))
    register_fused(FusedImpl(
        name=f"{_metric}.fusedk.xla", metric=_metric, kind="xla",
        backends=("cpu", "gpu", "tpu"),
        tuning=dict(_prec),
        workset_bytes=_ws_fused_xla, kernel_metric=_kmetric,
        description=f"one-jit {_metric} scan-of-scans: the megakernel "
                    "dataflow as a single XLA program (no per-cell host "
                    "sync; the off-TPU fused-kernel form; precision knobs "
                    "round-trip the feature slabs)",
    ))


def fused_feat_traffic_bytes(spec: FusedImpl, n: int, d: int, tuning=None,
                             row_block: int = 256) -> float:
    """Modelled HBM feature-slab bytes for ONE permutation chunk's sweep
    at the tuning dict's precision.

    Pallas megakernel: each (i, j) tile pair re-reads a (tile_r, d) and a
    (tile_c, d) slab at the slab's element width, so traffic ≈
    bpe*d*n*(n/tile_r + n/tile_c). XLA one-pass: each row block re-reads
    the full table once ≈ 4*d*n*(n/row_block + 1) — its precision knobs
    are value-parity round-trips (the slabs stream as f32), so no traffic
    credit. This is the planner's per-precision reporting model
    (plan.explain), not a hardware counter."""
    t = {**dict(spec.tuning), **(tuning or {})}
    if spec.kind == "pallas":
        bpe = feat_element_bytes(t)
        tr = int(t.get("tile_r", 128))
        tc = int(t.get("tile_c", 128))
        nti = -(-n // tr)
        ntj = -(-n // tc)
        return bpe * d * nti * ntj * (tr + tc)
    return 4.0 * d * n * (-(-n // max(int(row_block), 1)) + 1)


def fused_workset_bytes(spec: FusedImpl, n: int, d: int, chunk: int,
                        n_groups: int, row_block: int,
                        tuning=None) -> float:
    """Precision-aware peak-residency model: the base workset_bytes plus
    the resident feature tiles at the selected element width (the base
    FusedImpl.workset_bytes signature is frozen; this module-level form
    adds the precision term)."""
    base = spec.workset_bytes(n, d, chunk, n_groups, row_block)
    t = {**dict(spec.tuning), **(tuning or {})}
    if spec.kind == "pallas":
        bpe = feat_element_bytes(t)
        tr = int(t.get("tile_r", 128))
        tc = int(t.get("tile_c", 128))
        fb = int(t.get("feat_block", 128))
        return base + bpe * (tr + tc) * fb
    # xla: an active precision knob materializes one round-tripped f32
    # copy of the table during prepare
    return base + (4.0 * n * d if precision_tag(t) != "f32" else 0.0)
