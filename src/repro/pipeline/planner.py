"""Joint two-stage planner: distance construction + s_W under ONE plan.

PR 1's engine planner picks the s_W dataflow from the paper's Fig. 1 result
(CPU-tiled vs GPU-brute). On the full features→p-value pipeline that choice
is only half the problem: for large n the distance stage dominates wall
clock (ROADMAP), and — as the MI300A unified-memory literature stresses —
whole-pipeline DATAFLOW (what gets materialized, and where) decides whether
memory-heavy codes win on APU-class hardware. So this planner decides, in
one place:

  stage 1   which distance impl (dense / blocked / Pallas per backend and
            transient-memory model, mirroring tiled-vs-brute), and its
            row-block size
  bridge    the materialization strategy: 'dense' (D then mat2 — two (n,n)
            transients), 'stream' (square row blocks into ONE mat2 buffer;
            never resident twice), 'fused' (no (n,n) array at all; row
            slabs feed permutation chunks directly), or 'fused-kernel'
            (single-pass: distance tiles built AND contracted inside one
            program — the Pallas megakernel on TPU, a one-jit XLA sweep
            elsewhere — so D² slabs never round-trip through HBM)
  stage 2   the engine Plan (impl + tuning + streaming chunk) for s_W,
            delegated to repro.engine.planner — including its persisted
            autotune measurements

The fused-kernel plan is joint across every knob: tile_r/tile_c/feat_block/
perm_block come from the fused registry's defaults overlaid with persisted
autotune measurements (`autotune_stage1` / `autotune_fused` time candidates
on the real operands and park the winners in the same per-host cache the
engine planner uses, keyed by (backend, metric, impl)).

`plan_pipeline()` is pure shape/backend arithmetic, like `engine.plan()`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax

from repro.engine import planner as _eplanner
from repro.pipeline import registry as _dreg

# Matrix-residency budget for the bridge decision. Distinct from the engine's
# label budget: this one governs the O(n^2) distance operands.
DEFAULT_MATRIX_BUDGET_BYTES = 1024 * 1024 ** 2
# Transient slab budget for picking the row block (and the dense/blocked
# stage-1 cut on CPU, standing in for the paper's LLC argument).
DEFAULT_SLAB_BUDGET_BYTES = 128 * 1024 ** 2
MIN_ROW_BLOCK = 8
MAX_ROW_BLOCK = 4096
PALLAS_MIN_N = 256
# Residency budgets for the out-of-core decision: the f32 feature table must
# fit the device budget to run the resident bridges, and the host budget
# only grades the bandwidth model (page-cache-warm vs cold disk reads).
DEFAULT_DEVICE_BUDGET_BYTES = 2 * 1024 ** 3
DEFAULT_HOST_BUDGET_BYTES = 32 * 1024 ** 3

MATERIALIZE_MODES = ("dense", "stream", "fused", "fused-kernel")


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A resolved features→p-value execution plan."""
    metric: str
    dist_impl: str                # distance registry name
    dist_tuning: Dict[str, int]
    materialize: str              # 'dense' | 'stream' | 'fused' |
                                  # 'fused-kernel'
    row_block: int
    sw: _eplanner.Plan            # stage-2 engine plan
    backend: str
    reason: str
    fused_impl: Optional[str] = None      # fused registry name when the
                                          # bridge is 'fused-kernel'
    fused_tuning: Dict[str, int] = dataclasses.field(default_factory=dict)
    n: int = 0                            # problem shape (for explain())
    d: int = 0
    n_groups: int = 0
    residency: str = "hbm"                # where the features LIVE during
                                          # the sweep (registry tier)
    slab_rows: int = 0                    # on-disk slab height when the
                                          # features come from a slab cache
    disk_bytes: int = 0                   # slab-cache on-disk footprint

    def explain(self) -> str:
        """describe() plus the residency-tier bandwidth table (when the
        features stream from a slab cache) and the precision-aware
        memory-traffic model: the predicted feature-slab HBM bytes and
        peak workset per precision choice for the planned fused impl,
        with the planned one marked."""
        lines = [self.describe()]
        if self.residency != "hbm" and self.slab_rows and self.n:
            n_slabs = -(-self.n // self.slab_rows)
            traffic = _dreg.ooc_disk_traffic_bytes(n_slabs, self.disk_bytes)
            gbps = _dreg.tier_bandwidth_gbps(self.residency, self.backend)
            lines.append(
                f"residency: {self.residency} (features "
                f"{4 * self.n * self.d / 2**20:.0f} MiB f32 exceed the "
                f"device budget; {n_slabs} slabs x {self.slab_rows} rows)")
            lines.append("tier bandwidth model (GB/s): " + ", ".join(
                f"{t}={_dreg.tier_bandwidth_gbps(t, self.backend):.1f}"
                for t in _dreg.RESIDENCY_TIERS))
            lines.append(
                f"predicted slab-cache traffic per sweep: "
                f"{traffic / 2**20:.1f} MiB ({n_slabs + 1} passes over "
                f"{self.disk_bytes / 2**20:.1f} MiB on disk, independent "
                f"of n_perms), ~{traffic / (gbps * 1e9) * 1e3:.1f} ms at "
                f"the {self.residency} tier")
        if self.materialize != "fused-kernel" or not self.fused_impl \
                or not self.n:
            return "\n".join(lines)
        spec = _dreg.get_fused(self.fused_impl)
        planned = _dreg.precision_tag(self.fused_tuning)
        lines.append(
            f"predicted feature-slab HBM traffic per permutation chunk "
            f"(n={self.n}, d={self.d}, {spec.kind} kind):")
        for tag in _dreg.PRECISIONS:
            if tag == "packed" and spec.kernel_metric != "jaccard":
                continue
            t = {**self.fused_tuning, **_dreg.precision_tuning(tag)}
            traffic = _dreg.fused_feat_traffic_bytes(
                spec, self.n, self.d, t, self.row_block)
            workset = _dreg.fused_workset_bytes(
                spec, self.n, self.d, self.sw.chunk, self.n_groups,
                self.row_block, t)
            mark = "  <- planned" if tag == planned else ""
            lines.append(f"  {tag:>6}: {traffic/2**20:9.2f} MiB feat "
                         f"traffic, {workset/2**20:8.3f} MiB workset{mark}")
        return "\n".join(lines)

    def describe_stage1(self) -> str:
        """Stage 1 + bridge only — what the pipeline itself executes. The
        dense/stream bridges delegate stage 2 to engine.run, whose own plan
        record is authoritative there (autotune may override ours)."""
        if self.materialize == "fused-kernel":
            t = ",".join(f"{k}={v}"
                         for k, v in sorted(self.fused_tuning.items()))
            return (f"{self.fused_impl}[{t}] -> fused-kernel"
                    f"(rows={self.row_block})")
        t = ",".join(f"{k}={v}" for k, v in sorted(self.dist_tuning.items()))
        return (f"{self.dist_impl}[{t}] -> {self.materialize}"
                f"(rows={self.row_block})")

    def describe(self) -> str:
        return (f"{self.describe_stage1()} -> {self.sw.describe()}"
                f" | {self.reason}")


def _pick_dist_impl(metric: str, backend: str, n: int, d: int,
                    slab_budget: float):
    """Stage-1 impl by capability + transient model (Fig. 1 transplanted:
    bounded-working-set forms on CPU, widest forms on GPU, tiles on TPU).
    A persisted stage-1 shoot-out on this host overrides the model."""
    if metric not in _dreg.metrics():
        raise KeyError(f"unknown metric {metric!r}; "
                       f"registered: {_dreg.metrics()}")
    measured = measured_stage1(backend, metric, n)
    if measured is not None:
        return measured, ("persisted stage-1 autotune measurement "
                          f"({_eplanner.autotune_cache_path()})")
    if backend == "tpu" and n >= PALLAS_MIN_N and \
            _dreg.names(metric=metric, kind="pallas"):
        return (f"{metric}.pallas",
                "tiled Pallas kernel past the tile-viability point")
    dense = _dreg.get(f"{metric}.dense")
    # respect the registry's capability metadata: only consider the dense
    # form where it is registered as performant for this backend
    dense_ok = backend in dense.backends
    dense_ws = dense.workset_bytes(n, d, n)
    if dense_ok and backend == "gpu" and dense_ws <= slab_budget:
        return (f"{metric}.dense",
                "GPU prefers the widest form (Fig. 1 brute analogue)")
    if dense_ok and dense_ws <= min(slab_budget, _eplanner.CPU_LLC_BYTES):
        return (f"{metric}.dense",
                f"dense transients {dense_ws/2**20:.0f}MiB fit the cache "
                "model; single full-matrix form")
    why = (f"dense transients {dense_ws/2**20:.0f}MiB spill the slab/cache "
           "budget" if dense_ok else
           f"dense form not registered for backend {backend!r}")
    # blocked is the universal fallback: correct on every backend (its
    # `backends` field records where it is the PERFORMANT choice, not the
    # only places it runs), with the only bounded working set.
    return (f"{metric}.blocked",
            f"{why}; row-streaming form (Fig. 1 tiled analogue)")


def _pick_materialize(n: int, matrix_budget: float, metric: str):
    dense_bytes = 8 * n * n      # D + mat2 both live transiently
    mat2_bytes = 4 * n * n
    if dense_bytes <= matrix_budget:
        return "dense", (f"D+mat2 {dense_bytes/2**20:.0f}MiB fit the "
                         "matrix budget")
    if mat2_bytes <= matrix_budget:
        return "stream", (f"mat2 {mat2_bytes/2**20:.0f}MiB fits but D+mat2 "
                          "would not; stream row blocks into one buffer")
    why = (f"even one (n,n) buffer {mat2_bytes/2**20:.0f}MiB exceeds the "
           "matrix budget")
    if _dreg.fused_names(metric=metric):
        return "fused-kernel", (f"{why}; single-pass sweep (distance tiles "
                                "contracted in-kernel, D² never resident)")
    return "fused", (f"{why}; fuse row slabs into the permutation sweep")


def _pick_fused_impl(metric: str, backend: str, n: int,
                     tuning: Optional[Dict[str, int]] = None
                     ) -> Tuple[str, str]:
    """Fused-kernel impl: persisted shoot-out winner (at the requested
    precision), else the Pallas megakernel on TPU and the one-jit XLA
    sweep everywhere else."""
    measured = measured_fused(backend, metric, n, tuning)
    if measured is not None:
        return measured, "persisted fused-kernel autotune measurement"
    pallas = _dreg.fused_names(metric=metric, kind="pallas")
    if backend == "tpu" and n >= PALLAS_MIN_N and pallas:
        return pallas[0], "Pallas megakernel past the tile-viability point"
    xla = _dreg.fused_names(metric=metric, kind="xla")
    if not xla:  # pragma: no cover - every metric registers an xla form
        raise KeyError(f"no fused-kernel impl for metric {metric!r}")
    return xla[0], "one-jit XLA sweep (no kernel path on this backend)"


def _pick_row_block(n: int, d: int, impl: _dreg.DistanceImpl,
                    slab_budget: float) -> int:
    """Largest power-of-two row block whose transient working set fits."""
    block = MAX_ROW_BLOCK
    while block > MIN_ROW_BLOCK and \
            impl.workset_bytes(n, d, block) > slab_budget:
        block //= 2
    return max(MIN_ROW_BLOCK, min(block, n))


def plan_slab_rows(n: int, d: int, *,
                   device_budget_bytes: Optional[float] = None) -> int:
    """Slab height for BUILDING a cache destined for the OOC sweep: the
    largest power-of-two block whose live device footprint — one feature
    row slab + one column slab in flight plus the assembled (slab, n) m2
    row slab — stays a small fraction of the device budget, leaving the
    rest to the permutation chunks."""
    budget = (DEFAULT_DEVICE_BUDGET_BYTES if device_budget_bytes is None
              else device_budget_bytes)
    per_slab = budget / 16.0
    block = MAX_ROW_BLOCK
    while block > MIN_ROW_BLOCK and 4.0 * block * (2 * d + n) > per_slab:
        block //= 2
    return max(MIN_ROW_BLOCK, min(block, n))


def plan_pipeline(n: int, d: int, n_perms: int, n_groups: int, *,
                  metric: str = "braycurtis",
                  backend: Optional[str] = None,
                  dist_impl: Optional[str] = None,
                  materialize: Optional[str] = None,
                  row_block: Optional[int] = None,
                  matrix_budget_bytes: Optional[float] = None,
                  slab_budget_bytes: Optional[float] = None,
                  memory_budget_bytes: Optional[float] = None,
                  sw_impl: Optional[str] = None,
                  chunk: Optional[int] = None,
                  sw_tuning: Optional[Dict[str, int]] = None,
                  fused_impl: Optional[str] = None,
                  fused_tuning: Optional[Dict[str, int]] = None,
                  design_cols: Optional[int] = None,
                  features_on_disk: bool = False,
                  slab_rows: Optional[int] = None,
                  features_disk_bytes: Optional[int] = None,
                  device_budget_bytes: Optional[float] = None,
                  host_budget_bytes: Optional[float] = None
                  ) -> PipelinePlan:
    """Resolve the full two-stage plan for one problem.

    n_perms counts TOTAL permutation slots (requested + 1 observed), same
    convention as engine.plan(). Caller-pinned fields (dist_impl,
    materialize, row_block, sw_impl, chunk) are respected; the planner
    fills in the rest.

    design_cols: the dense-design basis width K (covariate/weighted/
    multi-factor designs) — the permutation-state workset models are
    sized for K design columns instead of G groups, and the engine plan
    is restricted to the matmul-family dense companions.

    features_on_disk: the features come from a slab cache (slab_rows is
    its build-time slab height, features_disk_bytes its on-disk size).
    The planner grades the residency tier from the f32 footprint against
    the device/host budgets; below 'hbm' it forces the out-of-core sweep:
    a fused bridge with row_block == slab_rows (the slab IS the row
    block) and the one-jit XLA form (the megakernel needs resident
    features).
    """
    backend = backend or _eplanner.default_backend()
    matrix_budget = (DEFAULT_MATRIX_BUDGET_BYTES
                     if matrix_budget_bytes is None else matrix_budget_bytes)
    slab_budget = (DEFAULT_SLAB_BUDGET_BYTES
                   if slab_budget_bytes is None else slab_budget_bytes)

    residency = "hbm"
    if features_on_disk:
        if not slab_rows:
            raise ValueError("features_on_disk=True requires slab_rows "
                             "(the cache's build-time slab height)")
        residency = _dreg.residency_tier(
            4.0 * n * d,
            device_budget_bytes=(DEFAULT_DEVICE_BUDGET_BYTES
                                 if device_budget_bytes is None
                                 else device_budget_bytes),
            host_budget_bytes=(DEFAULT_HOST_BUDGET_BYTES
                               if host_budget_bytes is None
                               else host_budget_bytes))
    ooc = residency != "hbm"
    if ooc:
        if materialize not in (None, "auto", "fused", "fused-kernel"):
            raise ValueError(
                f"features exceed the device budget (residency="
                f"{residency!r}); the {materialize!r} bridge needs a "
                "resident (n,n) operand — use materialize='auto'/'fused'/"
                "'fused-kernel' or raise device_budget_bytes")
        ooc_auto = materialize in (None, "auto")
        if ooc_auto:
            materialize = "fused-kernel"
        # The disk slab IS the unit of streaming: the sweep assembles one
        # (slab_rows, n) m2 row slab at a time, so the row block is not a
        # free knob out of core.
        row_block = int(slab_rows)

    if dist_impl is None or dist_impl == "auto":
        dname, dreason = _pick_dist_impl(metric, backend, n, d, slab_budget)
    else:
        dname = dist_impl if "." in dist_impl else f"{metric}.{dist_impl}"
        dreason = "caller-pinned distance impl"
    dspec = _dreg.get(dname)
    if dspec.metric != metric:
        raise ValueError(f"distance impl {dname!r} computes "
                         f"{dspec.metric!r}, not {metric!r}")
    if dspec.max_n is not None and n > dspec.max_n:
        raise ValueError(f"{dname!r} capped at n={dspec.max_n}, got {n}")

    mat_pinned = materialize not in (None, "auto")
    if not mat_pinned:
        mat, mreason = _pick_materialize(n, matrix_budget, metric)
    else:
        if materialize not in MATERIALIZE_MODES:
            raise ValueError(f"materialize={materialize!r}; expected one of "
                             f"{MATERIALIZE_MODES}")
        mat, mreason = materialize, "caller-pinned materialization"
        if ooc and ooc_auto:
            mreason = (f"features exceed the device budget (residency="
                       f"{residency}); out-of-core slab sweep")

    if row_block is None:
        # Size the row block against the ROWS working set: the stream/fused
        # bridges consume make_rows, whose transients scale with the block,
        # unlike a dense-kind impl's block-independent full-matrix model
        # (which would collapse the block to the minimum for nothing).
        rows_spec = (dspec if dspec.kind != "dense"
                     else _dreg.get(f"{metric}.blocked"))
        row_block = _pick_row_block(n, d, rows_spec, slab_budget)
    row_block = max(1, min(int(row_block), n))

    # Stage 2 via the engine planner (shares its persisted autotune state).
    # Both fused bridges compute s_W themselves in the one-hot matmul form,
    # so pin the engine plan to 'matmul' there — its chunk/budget arithmetic
    # still sizes the label blocks. A caller-pinned sw_impl that a fused
    # bridge cannot honor is a hard error when the bridge was pinned too,
    # and a downgrade to 'stream' when the bridge choice was ours.
    fused_modes = ("fused", "fused-kernel")
    pinned_sw = sw_impl if sw_impl not in (None, "auto") else None
    if mat in fused_modes and pinned_sw not in (None, "matmul"):
        if mat_pinned:
            raise ValueError(
                f"the {mat} bridge computes s_W in the one-hot matmul form "
                f"and cannot honor sw_impl={pinned_sw!r}; use "
                "sw_impl='auto'/'matmul' or materialize='stream'")
        mat = "stream"
        mreason += (f"; downgraded fused->stream to honor "
                    f"sw_impl={pinned_sw!r} (over matrix budget)")
    if mat in fused_modes and pinned_sw is None:
        pinned_sw = "matmul"
    if mat in fused_modes and chunk is None:
        # The fused step's working set is the one-hot block (chunk, n, G)
        # plus its (n, chunk*G) reshape — G-fold larger per permutation
        # than the engine's label-only model. Size the chunk against the
        # label budget with that factor so the fused sweep honors the same
        # memory contract. Dense designs swap G for the basis width K.
        budget = (_eplanner.DEFAULT_STREAM_BUDGET_BYTES
                  if memory_budget_bytes is None else memory_budget_bytes)
        cols = n_groups if design_cols is None else design_cols
        per_perm = 4.0 * n * (2 * cols + 1)
        chunk = int(max(1, min(budget // per_perm, n_perms)))
    sw = _eplanner.plan(n, n_perms, n_groups, backend=backend,
                        impl=pinned_sw,
                        memory_budget_bytes=memory_budget_bytes,
                        chunk=chunk, tuning=sw_tuning,
                        n_cols=design_cols)

    # Fused-kernel: resolve which single-pass impl runs the sweep and its
    # joint tile tuning (registry defaults <- persisted measurements <-
    # caller overrides).
    f_impl = None
    f_tuning: Dict[str, int] = {}
    if mat == "fused-kernel":
        if ooc and fused_impl in (None, "auto"):
            # The Pallas megakernel reads the whole resident feature table;
            # out of core only the one-jit XLA sweep applies (it consumes
            # the assembled m2 row slab).
            xla = _dreg.fused_names(metric=metric, kind="xla")
            if not xla:  # pragma: no cover - every metric registers one
                raise KeyError(f"no XLA fused impl for metric {metric!r}")
            f_impl, freason = xla[0], "one-jit XLA sweep over disk slabs"
        elif fused_impl in (None, "auto"):
            f_impl, freason = _pick_fused_impl(metric, backend, n,
                                               fused_tuning)
        else:
            f_impl = (fused_impl if "." in fused_impl
                      else f"{metric}.fusedk.{fused_impl}")
            freason = "caller-pinned fused impl"
        fspec = _dreg.get_fused(f_impl)
        if fspec.metric != metric:
            raise ValueError(f"fused impl {f_impl!r} computes "
                             f"{fspec.metric!r}, not {metric!r}")
        if ooc and fspec.kind != "xla":
            raise ValueError(
                f"fused impl {f_impl!r} ({fspec.kind} kind) needs the "
                "resident feature table; out-of-core sweeps require the "
                "XLA form")
        # Resolution order: registry defaults <- caller PRECISION knobs
        # (they select which measured entry applies) <- persisted tile
        # measurement at that precision <- caller tile overrides.
        f_tuning = dict(fspec.tuning)
        caller = ({k: v for k, v in fused_tuning.items() if k in f_tuning}
                  if fused_tuning else {})
        f_tuning.update(caller)
        entry = _eplanner.measured_entry(
            _fused_key(backend, metric, f_impl, f_tuning))
        if entry and isinstance(entry.get("tuning"), dict):
            f_tuning.update({k: int(v) for k, v in entry["tuning"].items()
                             if k in f_tuning})
        f_tuning.update(caller)
        mreason += f"; {freason}"

    # The planned row block IS the blocked impls' working-set knob — thread
    # it into the resolved tuning so every bridge (including dense, whose
    # builder scans the same row primitives) honors the slab budget.
    dist_tuning = dict(dspec.tuning)
    if "block" in dist_tuning:
        dist_tuning["block"] = row_block
    if ooc and _dreg.precision_tag(f_tuning) != "f32":
        raise ValueError(
            "out-of-core sweeps run f32 only: the reduced-precision slabs "
            "need a global calibration pass over the resident table")
    return PipelinePlan(
        metric=metric, dist_impl=dname, dist_tuning=dist_tuning,
        materialize=mat, row_block=row_block, sw=sw, backend=backend,
        reason=f"{dreason}; {mreason}", fused_impl=f_impl,
        fused_tuning=f_tuning, n=n, d=d, n_groups=n_groups,
        residency=residency, slab_rows=int(slab_rows or 0),
        disk_bytes=int(features_disk_bytes or 0))


# ---------------------------------------------------------------------------
# Persisted stage-1 / fused-kernel autotuning. Candidate timings live in the
# SAME per-host cache as the engine's s_W shoot-outs, one entry per
# (backend, metric, impl) key, so a serving host measures each candidate
# once ever and plan_pipeline() reads the winners back as its defaults.
# ---------------------------------------------------------------------------

def _stage1_key(backend: str, metric: str, impl: str) -> str:
    return f"dist|{backend}|{metric}|{impl}"


def _fused_key(backend: str, metric: str, impl: str,
               tuning: Optional[Dict[str, int]] = None) -> str:
    """Fused-kernel cache key. The precision knobs are part of the key —
    an fp8 timing must never feed an f32 plan — but the default (f32)
    precision keeps the historical untagged format so same-schema entries
    recorded before the precision knobs existed stay addressable."""
    tag = _dreg.precision_tag(tuning)
    base = f"fusedk|{backend}|{metric}|{impl}"
    return base if tag == "f32" else f"{base}|{tag}"


def _stage1_candidates(metric: str, backend: str):
    names = _dreg.names(metric=metric, kind="dense") + \
        _dreg.names(metric=metric, kind="blocked")
    if backend == "tpu":  # interpret-mode tiles are not a real candidate
        names += _dreg.names(metric=metric, kind="pallas")
    return names


def _argmin_measured(keys_by_name, n: int):
    """Winner among candidates whose persisted entry matches n's bucket.
    Requires EVERY candidate measured — a partial shoot-out must not
    short-circuit the heuristics."""
    bucket = _eplanner._bucket(n)
    times = {}
    for name, key in keys_by_name.items():
        entry = _eplanner.measured_entry(key)
        if not entry or entry.get("bucket") != bucket \
                or "us" not in entry:
            return None
        times[name] = entry["us"]
    return min(times, key=times.get) if times else None


def measured_stage1(backend: str, metric: str, n: int) -> Optional[str]:
    """Persisted stage-1 winner for this (backend, metric, n-bucket)."""
    cands = _stage1_candidates(metric, backend)
    return _argmin_measured(
        {c: _stage1_key(backend, metric, c) for c in cands}, n)


def measured_fused(backend: str, metric: str, n: int,
                   tuning: Optional[Dict[str, int]] = None) -> Optional[str]:
    """Persisted fused-kernel winner for this (backend, metric, n-bucket)
    at the precision the tuning knobs select (default f32)."""
    cands = [c for c in _dreg.fused_names(metric=metric)
             if backend in _dreg.get_fused(c).backends]
    return _argmin_measured(
        {c: _fused_key(backend, metric, c, tuning) for c in cands}, n)


def _time_call(fn, *args, **kw) -> float:
    jax.block_until_ready(fn(*args, **kw))   # compile + warm
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args, **kw))
    return time.perf_counter() - t0


def autotune_stage1(x, metric: str, *, backend: Optional[str] = None) -> str:
    """Time each stage-1 candidate's dense build on the real operands and
    persist one entry per (backend, metric, impl). Returns the winner."""
    import jax.numpy as jnp  # local: keep module import-light
    backend = backend or _eplanner.default_backend()
    x = jnp.asarray(x)
    n, d = (int(s) for s in x.shape)
    best, best_t = None, float("inf")
    for name in _stage1_candidates(metric, backend):
        spec = _dreg.get(name)
        _, _, dense_fn = spec.bound()
        try:
            t = _time_call(jax.jit(dense_fn), x)
        except Exception:  # noqa: BLE001 — an impl may not lower here
            continue
        _eplanner.record_entry(_stage1_key(backend, metric, name), {
            "impl": name, "us": round(t * 1e6, 1), "n": n, "d": d,
            "bucket": _eplanner._bucket(n)})
        if t < best_t:
            best, best_t = name, t
    if best is None:
        raise RuntimeError("autotune_stage1: no candidate ran successfully")
    return best


def autotune_fused(x, grouping, *, metric: str = "braycurtis",
                   backend: Optional[str] = None,
                   n_groups: Optional[int] = None,
                   sample_perms: int = 8,
                   key=None) -> str:
    """Time each fused-kernel candidate on a small permutation sample of
    the real operands; persist per-impl entries (timing + the tuning that
    achieved it) and return the winner."""
    import jax.numpy as jnp
    from repro.core import permutations as _perms
    from repro.pipeline import streaming as _streaming
    backend = backend or _eplanner.default_backend()
    x = jnp.asarray(x)
    grouping = jnp.asarray(grouping, jnp.int32)
    n, d = (int(s) for s in x.shape)
    if n_groups is None:
        n_groups = int(grouping.max()) + 1
    if key is None:
        key = jax.random.key(0)
    inv_gs = _perms.inv_group_sizes(grouping, n_groups)
    from repro.core import distance as _dist
    mdef = _dist.ROW_METRICS[metric]
    xprep = mdef.prepare(x)
    row_block = _pick_row_block(n, d, _dreg.get(f"{metric}.blocked"),
                                DEFAULT_SLAB_BUDGET_BYTES)
    best, best_t = None, float("inf")
    for name in _dreg.fused_names(metric=metric):
        spec = _dreg.get_fused(name)
        if backend not in spec.backends:
            continue
        tuning = dict(spec.tuning)

        def run():
            return _streaming.fused_kernel_sw(
                xprep, mdef.rows, grouping, inv_gs, key, sample_perms,
                impl=spec.kind, kernel_metric=spec.kernel_metric,
                row_block=row_block, chunk=sample_perms, tuning=tuning)

        try:
            run()                  # compile + warm (drivers host-sync)
            t0 = time.perf_counter()
            run()
            t = time.perf_counter() - t0
        except Exception:  # noqa: BLE001
            continue
        _eplanner.record_entry(_fused_key(backend, metric, name, tuning), {
            "impl": name, "us": round(t * 1e6, 1), "n": n, "d": d,
            "bucket": _eplanner._bucket(n), "tuning": tuning})
        if t < best_t:
            best, best_t = name, t
    if best is None:
        raise RuntimeError("autotune_fused: no candidate ran successfully")
    return best
