"""Joint two-stage planner: distance construction + s_W under ONE plan.

PR 1's engine planner picks the s_W dataflow from the paper's Fig. 1 result
(CPU-tiled vs GPU-brute). On the full features→p-value pipeline that choice
is only half the problem: for large n the distance stage dominates wall
clock (ROADMAP), and — as the MI300A unified-memory literature stresses —
whole-pipeline DATAFLOW (what gets materialized, and where) decides whether
memory-heavy codes win on APU-class hardware. So this planner decides, in
one place:

  stage 1   which distance impl (dense / blocked / Pallas per backend and
            transient-memory model, mirroring tiled-vs-brute), and its
            row-block size
  bridge    the materialization strategy: 'dense' (D then mat2 — two (n,n)
            transients), 'stream' (square row blocks into ONE mat2 buffer;
            never resident twice), or 'fused' (no (n,n) array at all;
            row slabs feed permutation chunks directly)
  stage 2   the engine Plan (impl + tuning + streaming chunk) for s_W,
            delegated to repro.engine.planner — including its persisted
            autotune measurements

`plan_pipeline()` is pure shape/backend arithmetic, like `engine.plan()`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.engine import planner as _eplanner
from repro.pipeline import registry as _dreg

# Matrix-residency budget for the bridge decision. Distinct from the engine's
# label budget: this one governs the O(n^2) distance operands.
DEFAULT_MATRIX_BUDGET_BYTES = 1024 * 1024 ** 2
# Transient slab budget for picking the row block (and the dense/blocked
# stage-1 cut on CPU, standing in for the paper's LLC argument).
DEFAULT_SLAB_BUDGET_BYTES = 128 * 1024 ** 2
MIN_ROW_BLOCK = 8
MAX_ROW_BLOCK = 4096
PALLAS_MIN_N = 256

MATERIALIZE_MODES = ("dense", "stream", "fused")


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A resolved features→p-value execution plan."""
    metric: str
    dist_impl: str                # distance registry name
    dist_tuning: Dict[str, int]
    materialize: str              # 'dense' | 'stream' | 'fused'
    row_block: int
    sw: _eplanner.Plan            # stage-2 engine plan
    backend: str
    reason: str

    def describe_stage1(self) -> str:
        """Stage 1 + bridge only — what the pipeline itself executes. The
        dense/stream bridges delegate stage 2 to engine.run, whose own plan
        record is authoritative there (autotune may override ours)."""
        t = ",".join(f"{k}={v}" for k, v in sorted(self.dist_tuning.items()))
        return (f"{self.dist_impl}[{t}] -> {self.materialize}"
                f"(rows={self.row_block})")

    def describe(self) -> str:
        return (f"{self.describe_stage1()} -> {self.sw.describe()}"
                f" | {self.reason}")


def _pick_dist_impl(metric: str, backend: str, n: int, d: int,
                    slab_budget: float):
    """Stage-1 impl by capability + transient model (Fig. 1 transplanted:
    bounded-working-set forms on CPU, widest forms on GPU, tiles on TPU)."""
    if metric not in _dreg.metrics():
        raise KeyError(f"unknown metric {metric!r}; "
                       f"registered: {_dreg.metrics()}")
    if backend == "tpu" and n >= PALLAS_MIN_N and \
            _dreg.names(metric=metric, kind="pallas"):
        return (f"{metric}.pallas",
                "tiled Pallas kernel past the tile-viability point")
    dense = _dreg.get(f"{metric}.dense")
    # respect the registry's capability metadata: only consider the dense
    # form where it is registered as performant for this backend
    dense_ok = backend in dense.backends
    dense_ws = dense.workset_bytes(n, d, n)
    if dense_ok and backend == "gpu" and dense_ws <= slab_budget:
        return (f"{metric}.dense",
                "GPU prefers the widest form (Fig. 1 brute analogue)")
    if dense_ok and dense_ws <= min(slab_budget, _eplanner.CPU_LLC_BYTES):
        return (f"{metric}.dense",
                f"dense transients {dense_ws/2**20:.0f}MiB fit the cache "
                "model; single full-matrix form")
    why = (f"dense transients {dense_ws/2**20:.0f}MiB spill the slab/cache "
           "budget" if dense_ok else
           f"dense form not registered for backend {backend!r}")
    # blocked is the universal fallback: correct on every backend (its
    # `backends` field records where it is the PERFORMANT choice, not the
    # only places it runs), with the only bounded working set.
    return (f"{metric}.blocked",
            f"{why}; row-streaming form (Fig. 1 tiled analogue)")


def _pick_materialize(n: int, matrix_budget: float):
    dense_bytes = 8 * n * n      # D + mat2 both live transiently
    mat2_bytes = 4 * n * n
    if dense_bytes <= matrix_budget:
        return "dense", (f"D+mat2 {dense_bytes/2**20:.0f}MiB fit the "
                         "matrix budget")
    if mat2_bytes <= matrix_budget:
        return "stream", (f"mat2 {mat2_bytes/2**20:.0f}MiB fits but D+mat2 "
                          "would not; stream row blocks into one buffer")
    return "fused", (f"even one (n,n) buffer {mat2_bytes/2**20:.0f}MiB "
                     "exceeds the matrix budget; fuse row slabs into the "
                     "permutation sweep")


def _pick_row_block(n: int, d: int, impl: _dreg.DistanceImpl,
                    slab_budget: float) -> int:
    """Largest power-of-two row block whose transient working set fits."""
    block = MAX_ROW_BLOCK
    while block > MIN_ROW_BLOCK and \
            impl.workset_bytes(n, d, block) > slab_budget:
        block //= 2
    return max(MIN_ROW_BLOCK, min(block, n))


def plan_pipeline(n: int, d: int, n_perms: int, n_groups: int, *,
                  metric: str = "braycurtis",
                  backend: Optional[str] = None,
                  dist_impl: Optional[str] = None,
                  materialize: Optional[str] = None,
                  row_block: Optional[int] = None,
                  matrix_budget_bytes: Optional[float] = None,
                  slab_budget_bytes: Optional[float] = None,
                  memory_budget_bytes: Optional[float] = None,
                  sw_impl: Optional[str] = None,
                  chunk: Optional[int] = None,
                  sw_tuning: Optional[Dict[str, int]] = None) -> PipelinePlan:
    """Resolve the full two-stage plan for one problem.

    n_perms counts TOTAL permutation slots (requested + 1 observed), same
    convention as engine.plan(). Caller-pinned fields (dist_impl,
    materialize, row_block, sw_impl, chunk) are respected; the planner
    fills in the rest.
    """
    backend = backend or _eplanner.default_backend()
    matrix_budget = (DEFAULT_MATRIX_BUDGET_BYTES
                     if matrix_budget_bytes is None else matrix_budget_bytes)
    slab_budget = (DEFAULT_SLAB_BUDGET_BYTES
                   if slab_budget_bytes is None else slab_budget_bytes)

    if dist_impl is None or dist_impl == "auto":
        dname, dreason = _pick_dist_impl(metric, backend, n, d, slab_budget)
    else:
        dname = dist_impl if "." in dist_impl else f"{metric}.{dist_impl}"
        dreason = "caller-pinned distance impl"
    dspec = _dreg.get(dname)
    if dspec.metric != metric:
        raise ValueError(f"distance impl {dname!r} computes "
                         f"{dspec.metric!r}, not {metric!r}")
    if dspec.max_n is not None and n > dspec.max_n:
        raise ValueError(f"{dname!r} capped at n={dspec.max_n}, got {n}")

    mat_pinned = materialize not in (None, "auto")
    if not mat_pinned:
        mat, mreason = _pick_materialize(n, matrix_budget)
    else:
        if materialize not in MATERIALIZE_MODES:
            raise ValueError(f"materialize={materialize!r}; expected one of "
                             f"{MATERIALIZE_MODES}")
        mat, mreason = materialize, "caller-pinned materialization"

    if row_block is None:
        # Size the row block against the ROWS working set: the stream/fused
        # bridges consume make_rows, whose transients scale with the block,
        # unlike a dense-kind impl's block-independent full-matrix model
        # (which would collapse the block to the minimum for nothing).
        rows_spec = (dspec if dspec.kind != "dense"
                     else _dreg.get(f"{metric}.blocked"))
        row_block = _pick_row_block(n, d, rows_spec, slab_budget)
    row_block = max(1, min(int(row_block), n))

    # Stage 2 via the engine planner (shares its persisted autotune state).
    # The fused bridge computes s_W itself in the one-hot matmul form, so
    # pin the engine plan to 'matmul' there — its chunk/budget arithmetic
    # still sizes the label blocks. A caller-pinned sw_impl that the fused
    # bridge cannot honor is a hard error when fused was pinned too, and a
    # downgrade to 'stream' when the bridge choice was ours.
    pinned_sw = sw_impl if sw_impl not in (None, "auto") else None
    if mat == "fused" and pinned_sw not in (None, "matmul"):
        if mat_pinned:
            raise ValueError(
                f"the fused bridge computes s_W in the one-hot matmul form "
                f"and cannot honor sw_impl={pinned_sw!r}; use "
                "sw_impl='auto'/'matmul' or materialize='stream'")
        mat = "stream"
        mreason += (f"; downgraded fused->stream to honor "
                    f"sw_impl={pinned_sw!r} (over matrix budget)")
    if mat == "fused" and pinned_sw is None:
        pinned_sw = "matmul"
    if mat == "fused" and chunk is None:
        # The fused step's working set is the one-hot block (chunk, n, G)
        # plus its (n, chunk*G) reshape — G-fold larger per permutation
        # than the engine's label-only model. Size the chunk against the
        # label budget with that factor so the fused sweep honors the same
        # memory contract.
        budget = (_eplanner.DEFAULT_STREAM_BUDGET_BYTES
                  if memory_budget_bytes is None else memory_budget_bytes)
        per_perm = 4.0 * n * (2 * n_groups + 1)
        chunk = int(max(1, min(budget // per_perm, n_perms)))
    sw = _eplanner.plan(n, n_perms, n_groups, backend=backend,
                        impl=pinned_sw,
                        memory_budget_bytes=memory_budget_bytes,
                        chunk=chunk, tuning=sw_tuning)

    # The planned row block IS the blocked impls' working-set knob — thread
    # it into the resolved tuning so every bridge (including dense, whose
    # builder scans the same row primitives) honors the slab budget.
    dist_tuning = dict(dspec.tuning)
    if "block" in dist_tuning:
        dist_tuning["block"] = row_block
    return PipelinePlan(
        metric=metric, dist_impl=dname, dist_tuning=dist_tuning,
        materialize=mat, row_block=row_block, sw=sw, backend=backend,
        reason=f"{dreason}; {mreason}")
