"""jit'd wrapper for the fused distance→s_W megakernel (with padding).

`fused_sw_rows` is the streaming unit the pipeline's fused-kernel bridge
consumes: s_W partials + Gower row sums for one permutation chunk over one
row slab, with the D² tiles never leaving VMEM. The slab is the whole table
in the single-host case (the kernel tiles rows internally) and a 'model'-
axis shard in the distributed case (`row_offset` is traced, so one compiled
program serves every shard).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_sw import kernel as _k

# aitchison is euclidean geometry over clr-prepared features
KERNEL_METRIC = {"euclidean": "euclidean", "braycurtis": "braycurtis",
                 "jaccard": "jaccard", "aitchison": "euclidean"}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick(v: int, cap: int) -> int:
    t = 1
    while t * 2 <= min(v, cap):
        t *= 2
    return max(t, 8)


def _feature_mode(metric, feat_bf16, feat_fp8, feat_packed) -> str:
    """Resolve the precision knobs to a kernel feat_mode (validating)."""
    if int(bool(feat_bf16)) + int(bool(feat_fp8)) + int(bool(feat_packed)) \
            > 1:
        raise ValueError(
            "feat_bf16 / feat_fp8 / feat_packed are mutually exclusive")
    if feat_packed:
        if metric != "jaccard":
            raise ValueError(
                "feat_packed=1 requires the jaccard kernel body "
                f"(got metric={metric!r})")
        return "packed"
    return "fp8" if feat_fp8 else "dense"


def _quantize_slabs(x_rows, x, metric, mode, feat_bf16, feat_scale):
    """Represent the prepared slabs at the requested precision.

    Returns (xr, xc, scale (1,1) f32). packed -> uint32 presence words
    (feature axis becomes words); fp8 -> float8_e4m3fn at the calibration
    scale (computed from the FULL table when not supplied, so every row
    slab of one study quantizes identically); dense -> f32/bf16."""
    from repro.core import distance as _dist
    if mode == "packed":
        return (_dist.pack_presence_bits(x_rows),
                _dist.pack_presence_bits(x),
                jnp.ones((1, 1), jnp.float32))
    if mode == "fp8":
        s = (_dist.fp8_metric_scale(x, metric) if feat_scale is None
             else jnp.asarray(feat_scale, jnp.float32))
        s = jnp.reshape(s, ())
        xr = (x_rows.astype(jnp.float32) / s).astype(jnp.float8_e4m3fn)
        xc = (x.astype(jnp.float32) / s).astype(jnp.float8_e4m3fn)
        return xr, xc, s.reshape(1, 1)
    dt = jnp.bfloat16 if feat_bf16 else jnp.float32
    return x_rows.astype(dt), x.astype(dt), jnp.ones((1, 1), jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "metric", "n_valid", "tile_r", "tile_c", "feat_block", "perm_block",
    "feat_bf16", "feat_fp8", "feat_packed", "interpret"))
def fused_sw_rows(x_rows, x, g_rows, g_cols, inv_gs, row_offset, *,
                  metric="braycurtis", n_valid=None, tile_r=128, tile_c=128,
                  feat_block=128, perm_block=16, feat_bf16: int = 0,
                  feat_fp8: int = 0, feat_packed: int = 0, feat_scale=None,
                  interpret: bool | None = None):
    """Fused s_W partial for one (row slab × permutation chunk) cell.

    x_rows:   (nr, d) prepared features of the slab's rows.
    x:        (n, d) prepared features of ALL samples (columns).
    g_rows:   (P, nr) int32 permuted labels at the slab's GLOBAL rows.
    g_cols:   (P, n) int32 permuted labels over all samples.
    inv_gs:   (G,) f32 inverse group sizes.
    row_offset: scalar global index of x_rows[0] (python int or traced).
    n_valid:  global sample count n (pad masking); defaults to x.shape[0].

    Precision knobs (the planner/autotune family; mutually exclusive):
    feat_bf16:   1 = bf16 feature slabs — halves HBM feature traffic,
                 fp32 accumulation; ~1e-2 rel drift on distances.
    feat_fp8:    1 = float8_e4m3fn slabs — quarters feature traffic.
                 Slabs are scaled by one per-study calibration scalar
                 (max|x|/448, computed once during prepare or passed as
                 feat_scale) and dequantized in-register; fp32
                 accumulation; ~1e-2 rel tolerance on F.
    feat_packed: 1 = packed uint32 presence words (jaccard only) —
                 32x feature-traffic cut, popcount tile bodies,
                 bit-identical results to the f32 matmul form.
    feat_scale:  optional traced f32 scalar pinning the fp8 calibration
                 (drivers compute it once per study, not per chunk).

    Returns (s_W (P,) f32, row_sums (nr,) f32). Summing the partials over
    disjoint row slabs reconstructs the full-statistic / full row sums.
    """
    metric = KERNEL_METRIC.get(metric, metric)
    mode = _feature_mode(metric, feat_bf16, feat_fp8, feat_packed)
    if interpret is None:
        interpret = not _on_tpu()
    nr = x_rows.shape[0]
    n = x.shape[0]
    p = g_cols.shape[0]
    if n_valid is None:
        n_valid = n
    xr, xc, scale = _quantize_slabs(x_rows, x, metric, mode, feat_bf16,
                                    feat_scale)
    d = xr.shape[1]                      # words when packed, else features
    tile_r = _pick(nr, tile_r)
    tile_c = _pick(n, tile_c)
    feat_block = _pick(d, feat_block)
    perm_block = min(perm_block, p)
    r_pad = (-nr) % tile_r
    c_pad = (-n) % tile_c
    d_pad = (-d) % feat_block
    p_pad = (-p) % perm_block
    xr = jnp.pad(xr, ((0, r_pad), (0, d_pad)))
    xc = jnp.pad(xc, ((0, c_pad), (0, d_pad)))
    # pad labels with 0s (masked D² zeroes those tiles' contributions) and
    # perms edge-mode (excess results sliced off)
    gr = jnp.pad(g_rows, ((0, 0), (0, r_pad)))
    gc = jnp.pad(g_cols, ((0, 0), (0, c_pad)))
    if p_pad:
        gr = jnp.pad(gr, ((0, p_pad), (0, 0)), mode="edge")
        gc = jnp.pad(gc, ((0, p_pad), (0, 0)), mode="edge")
    sqrt_w = jnp.sqrt(inv_gs.astype(jnp.float32)).reshape(1, -1)
    off = jnp.asarray(row_offset, jnp.int32).reshape(1, 1)
    sw, rs = _k.fused_sw_pallas(
        off, xr, xc, gr, gc, sqrt_w, metric=metric, n_valid=int(n_valid),
        nr_valid=nr, tile_r=tile_r, tile_c=tile_c, feat_block=feat_block,
        perm_block=perm_block, feat_mode=mode, feat_scale=scale,
        interpret=interpret)
    return sw[:p], rs[:nr]


@functools.partial(jax.jit, static_argnames=(
    "metric", "n_valid", "tile_r", "tile_c", "feat_block", "perm_block",
    "feat_bf16", "feat_fp8", "feat_packed", "interpret"))
def fused_sw_rows_cols(x_rows, x, v_rows, v_cols, row_offset, *,
                       metric="braycurtis", n_valid=None, tile_r=128,
                       tile_c=128, feat_block=128, perm_block=16,
                       feat_bf16: int = 0, feat_fp8: int = 0,
                       feat_packed: int = 0, feat_scale=None,
                       interpret: bool | None = None):
    """Dense-design fused partial: per-COLUMN quadratic forms for one
    (row slab × permutation chunk) cell (core.design hat-matrix blocks
    replacing the one-hot labels; the megakernel's MXU contraction
    consumes permuted basis blocks directly).

    v_rows: (P, nr, K) f32 permuted basis rows at the slab's GLOBAL rows.
    v_cols: (P, n, K) f32 permuted basis over all samples.
    feat_bf16/feat_fp8/feat_packed/feat_scale: feature-slab precision
    knobs, as documented on fused_sw_rows.
    Returns (s_cols (P, K) f32, row_sums (nr,) f32); summing partials
    over disjoint row slabs reconstructs the global per-column statistic.
    K is padded to a multiple of 8 lanes internally — zero basis columns
    contribute exactly zero and are sliced off.
    """
    metric = KERNEL_METRIC.get(metric, metric)
    mode = _feature_mode(metric, feat_bf16, feat_fp8, feat_packed)
    if interpret is None:
        interpret = not _on_tpu()
    nr = x_rows.shape[0]
    n = x.shape[0]
    p, _, k = v_cols.shape
    if n_valid is None:
        n_valid = n
    xr, xc, scale = _quantize_slabs(x_rows, x, metric, mode, feat_bf16,
                                    feat_scale)
    d = xr.shape[1]                      # words when packed, else features
    tile_r = _pick(nr, tile_r)
    tile_c = _pick(n, tile_c)
    feat_block = _pick(d, feat_block)
    perm_block = min(perm_block, p)
    r_pad = (-nr) % tile_r
    c_pad = (-n) % tile_c
    d_pad = (-d) % feat_block
    p_pad = (-p) % perm_block
    k_pad = (-k) % 8
    xr = jnp.pad(xr, ((0, r_pad), (0, d_pad)))
    xc = jnp.pad(xc, ((0, c_pad), (0, d_pad)))
    vr = jnp.pad(v_rows.astype(jnp.float32),
                 ((0, 0), (0, r_pad), (0, k_pad)))
    vc = jnp.pad(v_cols.astype(jnp.float32),
                 ((0, 0), (0, c_pad), (0, k_pad)))
    if p_pad:
        vr = jnp.pad(vr, ((0, p_pad), (0, 0), (0, 0)), mode="edge")
        vc = jnp.pad(vc, ((0, p_pad), (0, 0), (0, 0)), mode="edge")
    off = jnp.asarray(row_offset, jnp.int32).reshape(1, 1)
    sc, rs = _k.fused_sw_cols_pallas(
        off, xr, xc, vr, vc, metric=metric, n_valid=int(n_valid),
        nr_valid=nr, tile_r=tile_r, tile_c=tile_c, feat_block=feat_block,
        perm_block=perm_block, feat_mode=mode, feat_scale=scale,
        interpret=interpret)
    return sc[:p, :k], rs[:nr]
