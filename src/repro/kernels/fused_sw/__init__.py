"""Fused distance→s_W megakernel package.

kernel   the Pallas phase-grid megakernel (D² tiles never leave VMEM)
ops      jit'd padding/dispatch wrapper (`fused_sw_rows`)
ref      pure-jnp oracle for parity tests
"""

from repro.kernels.fused_sw.kernel import FUSED_METRICS  # noqa: F401
from repro.kernels.fused_sw.ops import KERNEL_METRIC, fused_sw_rows  # noqa: F401
from repro.kernels.fused_sw.ref import fused_sw_ref  # noqa: F401
