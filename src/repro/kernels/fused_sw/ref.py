"""Pure-jnp oracle for the fused distance→s_W megakernel.

Same contract as ops.fused_sw_rows, written the slow/obvious way: build the
dense distance slab from the core row primitives, mask by global index,
square, contract with the one-hot factors. Tests compare the kernel against
this at odd tile sizes, prime n, and ragged group counts.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import distance as _dist
from repro.core import fstat

ROWS_FNS = {"euclidean": _dist.euclidean_rows,
            "braycurtis": _dist.braycurtis_rows,
            "jaccard": _dist.jaccard_rows}


def fused_sw_ref(x_rows, x, g_rows, g_cols, inv_gs, row_offset, *,
                 metric="braycurtis", n_valid=None, feat_bf16=0, feat_fp8=0,
                 feat_packed=0, feat_scale=None):
    """(s_W (P,), row_sums (nr,)) for one row slab — the test oracle.

    The precision knobs mirror ops.fused_sw_rows by ROUND-TRIPPING the
    prepared features through the kernel's representation before the
    dense math: bf16/fp8 quantize-dequantize, packed is an exact no-op
    on presence data (the float matmul over round-tripped presence
    features IS the bit-exact packed oracle)."""
    metric = {"aitchison": "euclidean"}.get(metric, metric)
    nr = x_rows.shape[0]
    n = x.shape[0]
    if n_valid is None:
        n_valid = n
    xr = jnp.asarray(x_rows, jnp.float32)
    xc = jnp.asarray(x, jnp.float32)
    if feat_bf16:
        xr = xr.astype(jnp.bfloat16).astype(jnp.float32)
        xc = xc.astype(jnp.bfloat16).astype(jnp.float32)
    elif feat_fp8:
        s = (_dist.fp8_metric_scale(xc, metric) if feat_scale is None
             else feat_scale)
        xr = _dist.fp8_roundtrip(xr, s)
        xc = _dist.fp8_roundtrip(xc, s)
    elif feat_packed:
        xr = (xr > 0).astype(jnp.float32)
        xc = (xc > 0).astype(jnp.float32)
    d = ROWS_FNS[metric](xr, xc)
    rows_g = row_offset + jnp.arange(nr)[:, None]
    cols_g = jnp.arange(n)[None, :]
    valid = (rows_g < n_valid) & (cols_g < n_valid) & (rows_g != cols_g)
    m2 = jnp.where(valid, d * d, 0.0)
    e = fstat.onehot_perm_factors(g_cols, inv_gs, m2.dtype)      # (P, n, G)
    e_rows = fstat.onehot_perm_factors(g_rows, inv_gs, m2.dtype)
    return fstat.sw_matmul_contract(m2, e, e_rows), jnp.sum(m2, axis=1)
