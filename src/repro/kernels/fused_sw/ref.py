"""Pure-jnp oracle for the fused distance→s_W megakernel.

Same contract as ops.fused_sw_rows, written the slow/obvious way: build the
dense distance slab from the core row primitives, mask by global index,
square, contract with the one-hot factors. Tests compare the kernel against
this at odd tile sizes, prime n, and ragged group counts.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import distance as _dist
from repro.core import fstat

ROWS_FNS = {"euclidean": _dist.euclidean_rows,
            "braycurtis": _dist.braycurtis_rows,
            "jaccard": _dist.jaccard_rows}


def fused_sw_ref(x_rows, x, g_rows, g_cols, inv_gs, row_offset, *,
                 metric="braycurtis", n_valid=None):
    """(s_W (P,), row_sums (nr,)) for one row slab — the test oracle."""
    metric = {"aitchison": "euclidean"}.get(metric, metric)
    nr = x_rows.shape[0]
    n = x.shape[0]
    if n_valid is None:
        n_valid = n
    d = ROWS_FNS[metric](jnp.asarray(x_rows, jnp.float32),
                         jnp.asarray(x, jnp.float32))
    rows_g = row_offset + jnp.arange(nr)[:, None]
    cols_g = jnp.arange(n)[None, :]
    valid = (rows_g < n_valid) & (cols_g < n_valid) & (rows_g != cols_g)
    m2 = jnp.where(valid, d * d, 0.0)
    e = fstat.onehot_perm_factors(g_cols, inv_gs, m2.dtype)      # (P, n, G)
    e_rows = fstat.onehot_perm_factors(g_rows, inv_gs, m2.dtype)
    return fstat.sw_matmul_contract(m2, e, e_rows), jnp.sum(m2, axis=1)
