"""Single-pass Pallas megakernel: feature slabs → D² tiles → s_W partials.

PR 2's fused bridge avoids the (n, n) matrix but still round-trips every D²
row slab through HBM between the distance kernel and the s_W contraction.
This kernel closes that gap: a (tile_r, tile_c) squared-distance tile is
built from feature slabs and contracted into per-permutation s_W partials
(the one-hot matmul form) inside the same kernel, so D² tiles live only in
VMEM scratch and never touch HBM. The Gower row-sum marginals for s_T are
accumulated in the same sweep — one pass over the feature table yields
everything `fstat` needs.

Grid: (row-tile i, col-tile j, t) where the innermost t axis runs TWO
phases per (i, j) tile pair:

  t in [0, nk)        feature phase — accumulate the metric's running
                      sums over feature blocks into VMEM scratch; on the
                      last step finalize the masked D² tile (diagonal,
                      pad rows/cols zeroed by GLOBAL index) and bank the
                      Gower row sums
  t in [nk, nk+npb)   permutation phase — contract the resident D² tile
                      with one (perm_block, tile) label block per step on
                      the MXU, accumulating s_W in a VMEM scratch vector

Index maps clamp the out-of-phase block indices, so the feature operands
simply stay resident during the permutation phase and vice versa. The s_W
accumulator is flushed to HBM once, at the final grid step.

Metrics: euclidean (Gram trick — the accumulator IS D²), braycurtis
(|xi-xj| / (xi+xj) running sums), jaccard (presence/absence matmul form:
|A∩B| via the MXU, |A∪B| from cardinality sums). Aitchison rides the
euclidean body over clr-prepared features (ops layer maps it).

Row slabs are shardable: `row_offset` arrives as a traced SMEM scalar, so
a shard_map body can pass `axis_index('model') * rows_per_shard` and each
device sweeps only its row slab; summing the per-shard s_W partials (psum
over 'model') reconstructs the global statistic exactly (full i != j
symmetric sum, halved, zero diagonal).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

FUSED_METRICS = ("euclidean", "braycurtis", "jaccard")


FEAT_MODES = ("dense", "fp8", "packed")


def _accumulate(metric, feat_mode, scale, xr, xc, a_ref, b_ref):
    """One feature block's contribution to the metric's running sums.

    feat_mode selects the slab representation (static — each variant
    traces its own body):

      dense   f32 or bf16 slabs; MXU dot_generals consume them directly
              with fp32 accumulation, elementwise paths cast up first
      fp8     float8_e4m3fn slabs + one SMEM calibration scalar; tiles
              are dequantized in-register (cast-up x scale) so the
              running sums stay in real units with fp32 accumulation
      packed  uint32 presence words (jaccard only); |A∩B| via
              popcount(AND) and cardinalities via popcount row sums —
              exact integer counts, bit-identical to the f32 matmul form

    The accumulators are always fp32."""
    if feat_mode == "packed":
        if metric != "jaccard":  # pragma: no cover - ops validates
            raise ValueError("packed slabs require the jaccard body")
        inter = jnp.sum(
            jax.lax.population_count(xr[:, None, :] & xc[None, :, :]),
            axis=-1).astype(jnp.float32)
        card_r = jnp.sum(jax.lax.population_count(xr),
                         axis=-1).astype(jnp.float32)
        card_c = jnp.sum(jax.lax.population_count(xc),
                         axis=-1).astype(jnp.float32)
        a_ref[...] += inter
        b_ref[...] += card_r[:, None] + card_c[None, :]
        return
    if feat_mode == "fp8":
        xr = xr.astype(jnp.float32) * scale
        xc = xc.astype(jnp.float32) * scale
    xr32 = xr if xr.dtype == jnp.float32 else xr.astype(jnp.float32)
    xc32 = xc if xc.dtype == jnp.float32 else xc.astype(jnp.float32)
    if metric == "euclidean":
        sq_r = jnp.sum(xr32 * xr32, axis=-1)[:, None]
        sq_c = jnp.sum(xc32 * xc32, axis=-1)[None, :]
        gram = jax.lax.dot_general(                # MXU: (TR,FB)x(TC,FB)^T
            xr, xc, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        a_ref[...] += sq_r + sq_c - 2.0 * gram     # accumulator IS D²
    elif metric == "braycurtis":
        a_ref[...] += jnp.sum(jnp.abs(xr32[:, None, :] - xc32[None, :, :]),
                              axis=-1)
        b_ref[...] += jnp.sum(xr32[:, None, :] + xc32[None, :, :], axis=-1)
    elif metric == "jaccard":
        inter = jax.lax.dot_general(               # |A ∩ B| on the MXU
            xr, xc, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        a_ref[...] += inter
        b_ref[...] += (jnp.sum(xr32, axis=-1)[:, None]
                       + jnp.sum(xc32, axis=-1)[None, :])
    else:  # pragma: no cover - ops validates
        raise ValueError(metric)


def _finalize_d2(metric, a, b):
    """Squared distance tile from the completed running sums."""
    if metric == "euclidean":
        return jnp.maximum(a, 0.0)
    if metric == "braycurtis":
        d = a / jnp.maximum(b, 1e-30)
        return d * d
    # jaccard: union = card_r + card_c - inter
    d = 1.0 - a / jnp.maximum(b - a, 1.0)
    return d * d


def _fused_sw_body(off_ref, scale_ref, xr_ref, xc_ref, g_row_ref, g_col_ref,
                   sqrtw_ref, o_sw_ref, o_rs_ref, a_ref, b_ref, d2_ref,
                   sw_ref, *, metric, feat_mode, nk, npb, nti, ntj, tile_r,
                   tile_c, n_valid, nr_valid, n_groups):
    i = pl.program_id(0)
    j = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when((i == 0) & (j == 0) & (t == 0))
    def _init_sw():
        sw_ref[...] = jnp.zeros_like(sw_ref)

    @pl.when(t == 0)
    def _init_acc():
        a_ref[...] = jnp.zeros_like(a_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    @pl.when(t < nk)
    def _feature_phase():
        _accumulate(metric, feat_mode, scale_ref[0, 0], xr_ref[...],
                    xc_ref[...], a_ref, b_ref)

    @pl.when(t == nk - 1)
    def _finalize():
        row_off = off_ref[0, 0]
        rows_l = i * tile_r + jax.lax.broadcasted_iota(
            jnp.int32, (tile_r, tile_c), 0)
        rows_g = row_off + rows_l
        cols_g = j * tile_c + jax.lax.broadcasted_iota(
            jnp.int32, (tile_r, tile_c), 1)
        # slab pad rows (local id past the slab's true row count), global
        # pad cols, and the exact diagonal contribute nothing — the
        # contraction and row sums below both consume the masked tile
        valid = ((rows_l < nr_valid) & (rows_g < n_valid)
                 & (cols_g < n_valid) & (rows_g != cols_g))
        d2 = jnp.where(valid, _finalize_d2(metric, a_ref[...], b_ref[...]),
                       0.0)
        d2_ref[...] = d2
        rs = jnp.sum(d2, axis=1, keepdims=True).T       # (1, TR)

        @pl.when(j == 0)
        def _rs_init():
            o_rs_ref[...] = rs

        @pl.when(j > 0)
        def _rs_acc():
            o_rs_ref[...] += rs

    @pl.when(t >= nk)
    def _perm_phase():
        pb = t - nk
        g_r = g_row_ref[...]                            # (PB, TR)
        g_c = g_col_ref[...]                            # (PB, TC)
        sqrt_w = sqrtw_ref[0, :]                        # (G,)
        iota_g = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_groups), 2)
        e_col = (g_c[:, :, None] == iota_g).astype(jnp.float32) * sqrt_w
        e_row = (g_r[:, :, None] == iota_g).astype(jnp.float32) * sqrt_w
        # MXU contraction: (PB,TC,G) x (TR,TC) -> (PB, G, TR)
        y = jax.lax.dot_general(
            e_col, d2_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s = jnp.sum(y * jnp.transpose(e_row, (0, 2, 1)), axis=(1, 2))
        sw_ref[pb, :] += 0.5 * s

    @pl.when((i == nti - 1) & (j == ntj - 1) & (t == nk + npb - 1))
    def _flush():
        o_sw_ref[...] = sw_ref[...]


def fused_sw_pallas(row_offset, xr, xc, g_rows, g_cols, sqrt_w, *,
                    metric, n_valid, nr_valid, tile_r=128, tile_c=128,
                    feat_block=128, perm_block=16, feat_mode="dense",
                    feat_scale=None, interpret=True):
    """Launch the megakernel over pre-padded operands.

    row_offset: (1, 1) int32 — global index of xr's first row (traced OK).
    xr:      (nr_pad, d_pad) prepared row-slab features (f32/bf16 dense,
             float8_e4m3fn for feat_mode='fp8', uint32 words for 'packed').
    xc:      (nc_pad, d_pad) prepared full feature table (same dtype).
    g_rows:  (p_pad, nr_pad) int32 permuted labels at the slab's rows.
    g_cols:  (p_pad, nc_pad) int32 permuted labels over all samples.
    sqrt_w:  (1, G) f32 sqrt(inv_group_sizes).
    feat_scale: (1, 1) f32 fp8 calibration scalar (ignored otherwise).
    Returns (s_W (p_pad,) f32, row_sums (nr_pad,) f32) — pad entries zero.
    """
    if metric not in FUSED_METRICS:
        raise ValueError(f"unknown fused metric {metric!r}; "
                         f"one of {FUSED_METRICS}")
    if feat_mode not in FEAT_MODES:
        raise ValueError(f"unknown feat_mode {feat_mode!r}; "
                         f"one of {FEAT_MODES}")
    if feat_scale is None:
        feat_scale = jnp.ones((1, 1), jnp.float32)
    nr, d = xr.shape
    nc = xc.shape[0]
    p_pad = g_cols.shape[0]
    n_groups = sqrt_w.shape[-1]
    nti, ntj = nr // tile_r, nc // tile_c
    nk, npb = d // feat_block, p_pad // perm_block
    kernel = functools.partial(
        _fused_sw_body, metric=metric, feat_mode=feat_mode, nk=nk, npb=npb,
        nti=nti, ntj=ntj, tile_r=tile_r, tile_c=tile_c, n_valid=n_valid,
        nr_valid=nr_valid, n_groups=n_groups)
    out_sw, out_rs = pl.pallas_call(
        kernel,
        grid=(nti, ntj, nk + npb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((tile_r, feat_block),
                         lambda i, j, t: (i, jnp.minimum(t, nk - 1))),
            pl.BlockSpec((tile_c, feat_block),
                         lambda i, j, t: (j, jnp.minimum(t, nk - 1))),
            pl.BlockSpec((perm_block, tile_r),
                         lambda i, j, t: (jnp.clip(t - nk, 0, npb - 1), i)),
            pl.BlockSpec((perm_block, tile_c),
                         lambda i, j, t: (jnp.clip(t - nk, 0, npb - 1), j)),
            pl.BlockSpec((1, n_groups), lambda i, j, t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((npb, perm_block), lambda i, j, t: (0, 0)),
            pl.BlockSpec((1, tile_r), lambda i, j, t: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npb, perm_block), jnp.float32),
            jax.ShapeDtypeStruct((1, nr), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_r, tile_c), jnp.float32),   # metric accum a
            pltpu.VMEM((tile_r, tile_c), jnp.float32),   # metric accum b
            pltpu.VMEM((tile_r, tile_c), jnp.float32),   # masked D² tile
            pltpu.VMEM((npb, perm_block), jnp.float32),  # s_W accumulator
        ],
        interpret=interpret,
    )(row_offset, feat_scale, xr, xc, g_rows, g_cols, sqrt_w)
    return out_sw.reshape(-1), out_rs[0]


# ---------------------------------------------------------------------------
# Dense-design variant: the perm phase contracts PERMUTED BASIS blocks
# (hat-matrix factor columns, core.design) instead of building one-hot
# factors from labels. Feature phase, D² scratch residency and Gower row
# sums are identical; the output keeps the per-column axis so the host can
# slice per-term partial statistics.
# ---------------------------------------------------------------------------

def _fused_sw_cols_body(off_ref, scale_ref, xr_ref, xc_ref, vr_ref, vc_ref,
                        o_sw_ref, o_rs_ref, a_ref, b_ref, d2_ref, sw_ref, *,
                        metric, feat_mode, nk, npb, nti, ntj, tile_r, tile_c,
                        n_valid, nr_valid, k_cols):
    i = pl.program_id(0)
    j = pl.program_id(1)
    t = pl.program_id(2)
    # Sharded row slabs can end with fully-dead tiles (every global row
    # past n_valid): skip their feature accumulation and perm contraction
    # entirely. Finalize still runs — a/b are zero-initialized and the
    # validity mask zeroes the whole tile, so the banked row sums stay 0.
    row_live = off_ref[0, 0] + i * tile_r < n_valid

    @pl.when((i == 0) & (j == 0) & (t == 0))
    def _init_sw():
        sw_ref[...] = jnp.zeros_like(sw_ref)

    @pl.when(t == 0)
    def _init_acc():
        a_ref[...] = jnp.zeros_like(a_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    @pl.when((t < nk) & row_live)
    def _feature_phase():
        _accumulate(metric, feat_mode, scale_ref[0, 0], xr_ref[...],
                    xc_ref[...], a_ref, b_ref)

    @pl.when(t == nk - 1)
    def _finalize():
        row_off = off_ref[0, 0]
        rows_l = i * tile_r + jax.lax.broadcasted_iota(
            jnp.int32, (tile_r, tile_c), 0)
        rows_g = row_off + rows_l
        cols_g = j * tile_c + jax.lax.broadcasted_iota(
            jnp.int32, (tile_r, tile_c), 1)
        valid = ((rows_l < nr_valid) & (rows_g < n_valid)
                 & (cols_g < n_valid) & (rows_g != cols_g))
        d2 = jnp.where(valid, _finalize_d2(metric, a_ref[...], b_ref[...]),
                       0.0)
        d2_ref[...] = d2
        rs = jnp.sum(d2, axis=1, keepdims=True).T       # (1, TR)

        @pl.when(j == 0)
        def _rs_init():
            o_rs_ref[...] = rs

        @pl.when(j > 0)
        def _rs_acc():
            o_rs_ref[...] += rs

    @pl.when((t >= nk) & row_live)
    def _perm_phase():
        pb = t - nk
        v_r = vr_ref[...]                               # (PB, TR, K)
        v_c = vc_ref[...]                               # (PB, TC, K)
        # MXU contraction: (PB,TC,K) x (TR,TC) -> (PB, K, TR)
        y = jax.lax.dot_general(
            v_c, d2_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s = jnp.sum(y * jnp.transpose(v_r, (0, 2, 1)), axis=2)   # (PB, K)
        sw_ref[pb, :, :] += 0.5 * s

    @pl.when((i == nti - 1) & (j == ntj - 1) & (t == nk + npb - 1))
    def _flush():
        o_sw_ref[...] = sw_ref[...]


def fused_sw_cols_pallas(row_offset, xr, xc, v_rows, v_cols, *,
                         metric, n_valid, nr_valid, tile_r=128, tile_c=128,
                         feat_block=128, perm_block=16, feat_mode="dense",
                         feat_scale=None, interpret=True):
    """Launch the dense-design megakernel over pre-padded operands.

    v_rows: (p_pad, nr_pad, K) f32 permuted basis rows at the slab's rows.
    v_cols: (p_pad, nc_pad, K) f32 permuted basis over all samples.
    feat_mode/feat_scale: slab precision, as in fused_sw_pallas.
    Returns (s_cols (p_pad, K) f32 per-column partials, row_sums
    (nr_pad,) f32) — pad entries zero (zero basis rows/cols contribute
    exactly nothing, which is what keeps ragged studies bit-exact)."""
    if metric not in FUSED_METRICS:
        raise ValueError(f"unknown fused metric {metric!r}; "
                         f"one of {FUSED_METRICS}")
    if feat_mode not in FEAT_MODES:
        raise ValueError(f"unknown feat_mode {feat_mode!r}; "
                         f"one of {FEAT_MODES}")
    if feat_scale is None:
        feat_scale = jnp.ones((1, 1), jnp.float32)
    nr, d = xr.shape
    nc = xc.shape[0]
    p_pad = v_cols.shape[0]
    k_cols = v_cols.shape[-1]
    nti, ntj = nr // tile_r, nc // tile_c
    nk, npb = d // feat_block, p_pad // perm_block
    kernel = functools.partial(
        _fused_sw_cols_body, metric=metric, feat_mode=feat_mode, nk=nk,
        npb=npb, nti=nti, ntj=ntj, tile_r=tile_r, tile_c=tile_c,
        n_valid=n_valid, nr_valid=nr_valid, k_cols=k_cols)
    out_sw, out_rs = pl.pallas_call(
        kernel,
        grid=(nti, ntj, nk + npb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((tile_r, feat_block),
                         lambda i, j, t: (i, jnp.minimum(t, nk - 1))),
            pl.BlockSpec((tile_c, feat_block),
                         lambda i, j, t: (j, jnp.minimum(t, nk - 1))),
            pl.BlockSpec((perm_block, tile_r, k_cols),
                         lambda i, j, t: (jnp.clip(t - nk, 0, npb - 1),
                                          i, 0)),
            pl.BlockSpec((perm_block, tile_c, k_cols),
                         lambda i, j, t: (jnp.clip(t - nk, 0, npb - 1),
                                          j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((npb, perm_block, k_cols),
                         lambda i, j, t: (0, 0, 0)),
            pl.BlockSpec((1, tile_r), lambda i, j, t: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npb, perm_block, k_cols), jnp.float32),
            jax.ShapeDtypeStruct((1, nr), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_r, tile_c), jnp.float32),   # metric accum a
            pltpu.VMEM((tile_r, tile_c), jnp.float32),   # metric accum b
            pltpu.VMEM((tile_r, tile_c), jnp.float32),   # masked D² tile
            pltpu.VMEM((npb, perm_block, k_cols), jnp.float32),  # s_cols
        ],
        interpret=interpret,
    )(row_offset, feat_scale, xr, xc, v_rows, v_cols)
    return out_sw.reshape(-1, k_cols), out_rs[0]
