from repro.kernels.distance.ops import pairwise_distance  # noqa: F401
