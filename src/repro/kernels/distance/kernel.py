"""Pallas kernels for pairwise distance-matrix construction.

The distance matrix is the PERMANOVA input (the paper consumed a UniFrac
matrix computed elsewhere; Bray-Curtis/Euclidean are the standard in-framework
metrics). Tiling: grid (row-tile, col-tile, feature-block); the feature axis
is innermost so numerator/denominator accumulate in VMEM and the final
divide/sqrt happens once on the last feature step.

The kernels are rectangular: `xr` (nr, d) rows against `xc` (nc, d) columns.
The dense (n, n) matrix is the xr is xc special case; the pipeline's
streaming builder feeds row slabs (block, d) against the full table so the
distance stage can produce `D²` row blocks without ever materializing the
square matrix (repro.pipeline.streaming).

Euclidean uses the MXU (gram-trick inside the tile); Bray-Curtis is a pure
VPU streaming kernel (|xi - xj| has no matmul form). Jaccard is the
presence/absence matmul form: on 0/1 features the float product IS the set
intersection, so |A ∩ B| accumulates on the MXU and |A ∪ B| falls out of
the cardinality sums — every registered metric has a tiled stage-1 impl.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _braycurtis_body(xr_ref, xc_ref, out_ref, num_ref, den_ref, *,
                     n_feat_blocks):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    xr = xr_ref[...]                                # (TR, FB)
    xc = xc_ref[...]                                # (TC, FB)
    diff = jnp.abs(xr[:, None, :] - xc[None, :, :])
    summ = xr[:, None, :] + xc[None, :, :]
    num_ref[...] += jnp.sum(diff, axis=-1)
    den_ref[...] += jnp.sum(summ, axis=-1)

    @pl.when(k == n_feat_blocks - 1)
    def _finish():
        out_ref[...] = num_ref[...] / jnp.maximum(den_ref[...], 1e-30)


def braycurtis_pallas(xr, xc, *, tile_r=128, tile_c=128, feat_block=128,
                      interpret=True):
    nr, d = xr.shape
    nc = xc.shape[0]
    grid = (nr // tile_r, nc // tile_c, d // feat_block)
    kernel = functools.partial(_braycurtis_body, n_feat_blocks=grid[2])
    out, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, feat_block), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_c, feat_block), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),  # distances
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),  # numerator accum
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),  # denominator accum
        ],
        interpret=interpret,
    )(xr, xc)
    return out


def _jaccard_body(xr_ref, xc_ref, out_ref, inter_ref, card_ref, *,
                  n_feat_blocks):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        inter_ref[...] = jnp.zeros_like(inter_ref)
        card_ref[...] = jnp.zeros_like(card_ref)

    xr = xr_ref[...]                                # (TR, FB) presence 0/1
    xc = xc_ref[...]                                # (TC, FB)
    inter = jax.lax.dot_general(                    # MXU: |A ∩ B| per pair
        xr, xc, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    inter_ref[...] += inter
    card_ref[...] += (jnp.sum(xr, axis=-1)[:, None]
                      + jnp.sum(xc, axis=-1)[None, :])

    @pl.when(k == n_feat_blocks - 1)
    def _finish():
        inter = inter_ref[...]
        union = card_ref[...] - inter               # |A ∪ B|
        out_ref[...] = 1.0 - inter / jnp.maximum(union, 1.0)


def jaccard_pallas(xr, xc, *, tile_r=128, tile_c=128, feat_block=128,
                   interpret=True):
    """xr/xc must be presence/absence floats (distance.presence_prepare)."""
    nr, d = xr.shape
    nc = xc.shape[0]
    grid = (nr // tile_r, nc // tile_c, d // feat_block)
    kernel = functools.partial(_jaccard_body, n_feat_blocks=grid[2])
    out, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, feat_block), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_c, feat_block), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),  # distances
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),  # intersection accum
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),  # cardinality accum
        ],
        interpret=interpret,
    )(xr, xc)
    return out


def _jaccard_packed_body(xr_ref, xc_ref, out_ref, inter_ref, card_ref, *,
                         n_feat_blocks):
    """Packed-bit jaccard tile: uint32 presence words, popcount forms.

    The intersection/cardinality counts are exact integers (≤ d ≤ 2^24),
    so their f32 accumulation is exact and the finalize arithmetic is
    IDENTICAL to _jaccard_body's — the packed path is bit-identical to
    the float matmul form while moving 32x fewer feature bytes."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        inter_ref[...] = jnp.zeros_like(inter_ref)
        card_ref[...] = jnp.zeros_like(card_ref)

    xr = xr_ref[...]                                # (TR, WB) uint32 words
    xc = xc_ref[...]                                # (TC, WB)
    inter = jnp.sum(                                # |A ∩ B| = popcount(AND)
        jax.lax.population_count(xr[:, None, :] & xc[None, :, :]),
        axis=-1).astype(jnp.float32)
    inter_ref[...] += inter
    card_r = jnp.sum(jax.lax.population_count(xr),
                     axis=-1).astype(jnp.float32)
    card_c = jnp.sum(jax.lax.population_count(xc),
                     axis=-1).astype(jnp.float32)
    card_ref[...] += card_r[:, None] + card_c[None, :]

    @pl.when(k == n_feat_blocks - 1)
    def _finish():
        inter = inter_ref[...]
        union = card_ref[...] - inter               # |A ∪ B|
        out_ref[...] = 1.0 - inter / jnp.maximum(union, 1.0)


def jaccard_packed_pallas(xr, xc, *, tile_r=128, tile_c=128, feat_block=128,
                          interpret=True):
    """xr/xc are (rows, words) uint32 packed presence slabs
    (distance.pack_presence_bits); feat_block counts WORDS here."""
    nr, d = xr.shape
    nc = xc.shape[0]
    grid = (nr // tile_r, nc // tile_c, d // feat_block)
    kernel = functools.partial(_jaccard_packed_body, n_feat_blocks=grid[2])
    out, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, feat_block), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_c, feat_block), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),  # distances
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),  # intersection accum
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),  # cardinality accum
        ],
        interpret=interpret,
    )(xr, xc)
    return out


def _euclidean_body(xr_ref, xc_ref, out_ref, acc_ref, *, n_feat_blocks):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xr = xr_ref[...]
    xc = xc_ref[...]
    sq_r = jnp.sum(xr * xr, axis=-1)[:, None]
    sq_c = jnp.sum(xc * xc, axis=-1)[None, :]
    gram = jax.lax.dot_general(                     # MXU: (TR,FB)x(TC,FB)^T
        xr, xc, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] += sq_r + sq_c - 2.0 * gram

    @pl.when(k == n_feat_blocks - 1)
    def _finish():
        out_ref[...] = jnp.sqrt(jnp.maximum(acc_ref[...], 0.0))


def euclidean_pallas(xr, xc, *, tile_r=128, tile_c=128, feat_block=128,
                     interpret=True):
    nr, d = xr.shape
    nc = xc.shape[0]
    grid = (nr // tile_r, nc // tile_c, d // feat_block)
    kernel = functools.partial(_euclidean_body, n_feat_blocks=grid[2])
    out, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, feat_block), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_c, feat_block), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile_r, tile_c), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),
            jax.ShapeDtypeStruct((nr, nc), jnp.float32),
        ],
        interpret=interpret,
    )(xr, xc)
    return out
