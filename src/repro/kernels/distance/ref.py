"""Pure-jnp oracles for the pairwise-distance Pallas kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import distance as _d


def braycurtis_ref(x: jax.Array) -> jax.Array:
    return _d.braycurtis(x)


def euclidean_ref(x: jax.Array) -> jax.Array:
    return _d.euclidean(x)


def jaccard_ref(x: jax.Array) -> jax.Array:
    return _d.jaccard(x)
