"""jit'd wrappers for the pairwise-distance Pallas kernels (with padding).

Two entry points:

  pairwise_distance       (n, n) dense matrix from (n, d) features
  pairwise_distance_rows  (block, n) row slab — the streaming unit the
                          pipeline subsystem consumes to build D² blockwise
                          without materializing the full matrix
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance import kernel as _k

_KERNELS = {
    "braycurtis": _k.braycurtis_pallas,
    "euclidean": _k.euclidean_pallas,
    "jaccard": _k.jaccard_pallas,
}
PALLAS_METRICS = tuple(_KERNELS)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick(v: int, cap: int) -> int:
    t = 1
    while t * 2 <= min(v, cap):
        t *= 2
    return max(t, 8)


@functools.partial(jax.jit, static_argnames=("metric", "tile_r", "tile_c",
                                             "feat_block", "interpret"))
def pairwise_distance(x, *, metric="braycurtis", tile_r=128, tile_c=128,
                      feat_block=128, interpret: bool | None = None):
    """(n, n) distance matrix from (n, d) features via the Pallas kernels.

    Pads n/d to tile multiples; zero-padded features are exact for every
    metric (|0-0| = 0, zero presence bits intersect/union nothing; pad
    rows are sliced off). Jaccard expects presence/absence floats
    (distance.presence_prepare) — the registry's prepare supplies them.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if metric not in _KERNELS:
        raise ValueError(f"unknown metric {metric!r}")
    n, d = x.shape
    tile_r = _pick(n, tile_r)
    tile_c = _pick(n, tile_c)
    feat_block = _pick(d, feat_block)
    n_pad = (-n) % max(tile_r, tile_c)
    d_pad = (-d) % feat_block
    xp = jnp.pad(x.astype(jnp.float32), ((0, n_pad), (0, d_pad)))
    out = _KERNELS[metric](xp, xp, tile_r=tile_r, tile_c=tile_c,
                           feat_block=feat_block, interpret=interpret)
    out = out[:n, :n]
    return out * (1.0 - jnp.eye(n, dtype=out.dtype))  # exact zero diagonal


@functools.partial(jax.jit, static_argnames=("metric", "tile_r", "tile_c",
                                             "feat_block", "interpret"))
def pairwise_distance_rows(x_rows, x, *, metric="braycurtis", tile_r=128,
                           tile_c=128, feat_block=128,
                           interpret: bool | None = None):
    """(block, n) distances of a row slab against the full table.

    NOTE: no diagonal zeroing — the slab does not know its global row
    offset; the streaming consumer masks the (global_row == col) entries
    (repro.pipeline.streaming does this while squaring into D²).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if metric not in _KERNELS:
        raise ValueError(f"unknown metric {metric!r}")
    b, d = x_rows.shape
    n = x.shape[0]
    tile_r = _pick(b, tile_r)
    tile_c = _pick(n, tile_c)
    feat_block = _pick(d, feat_block)
    b_pad = (-b) % tile_r
    n_pad = (-n) % tile_c
    d_pad = (-d) % feat_block
    xr = jnp.pad(x_rows.astype(jnp.float32), ((0, b_pad), (0, d_pad)))
    xc = jnp.pad(x.astype(jnp.float32), ((0, n_pad), (0, d_pad)))
    out = _KERNELS[metric](xr, xc, tile_r=tile_r, tile_c=tile_c,
                           feat_block=feat_block, interpret=interpret)
    return out[:b, :n]
