"""jit'd wrappers for the pairwise-distance Pallas kernels (with padding).

Two entry points:

  pairwise_distance       (n, n) dense matrix from (n, d) features
  pairwise_distance_rows  (block, n) row slab — the streaming unit the
                          pipeline subsystem consumes to build D² blockwise
                          without materializing the full matrix
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import distance as _dist
from repro.kernels.distance import kernel as _k

_KERNELS = {
    "braycurtis": _k.braycurtis_pallas,
    "euclidean": _k.euclidean_pallas,
    "jaccard": _k.jaccard_pallas,
}
PALLAS_METRICS = tuple(_KERNELS)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick(v: int, cap: int) -> int:
    t = 1
    while t * 2 <= min(v, cap):
        t *= 2
    return max(t, 8)


def _check_packed(metric, packed):
    if packed and metric != "jaccard":
        raise ValueError(
            f"packed=1 requires metric='jaccard' (got {metric!r})")


@functools.partial(jax.jit, static_argnames=("metric", "tile_r", "tile_c",
                                             "feat_block", "packed",
                                             "interpret"))
def pairwise_distance(x, *, metric="braycurtis", tile_r=128, tile_c=128,
                      feat_block=128, packed: int = 0,
                      interpret: bool | None = None):
    """(n, n) distance matrix from (n, d) features via the Pallas kernels.

    Pads n/d to tile multiples; zero-padded features are exact for every
    metric (|0-0| = 0, zero presence bits intersect/union nothing; pad
    rows are sliced off). Jaccard expects presence/absence floats
    (distance.presence_prepare) — the registry's prepare supplies them.
    packed=1 (jaccard only) packs presence into uint32 words and runs the
    popcount tile body — bit-identical distances, 32x fewer feature bytes
    (feat_block then counts words).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if metric not in _KERNELS:
        raise ValueError(f"unknown metric {metric!r}")
    _check_packed(metric, packed)
    n = x.shape[0]
    if packed:
        xq = _dist.pack_presence_bits(x)
        kern = _k.jaccard_packed_pallas
    else:
        xq = x.astype(jnp.float32)
        kern = _KERNELS[metric]
    d = xq.shape[1]
    tile_r = _pick(n, tile_r)
    tile_c = _pick(n, tile_c)
    feat_block = _pick(d, feat_block)
    n_pad = (-n) % max(tile_r, tile_c)
    d_pad = (-d) % feat_block
    xp = jnp.pad(xq, ((0, n_pad), (0, d_pad)))
    out = kern(xp, xp, tile_r=tile_r, tile_c=tile_c,
               feat_block=feat_block, interpret=interpret)
    out = out[:n, :n]
    return out * (1.0 - jnp.eye(n, dtype=out.dtype))  # exact zero diagonal


@functools.partial(jax.jit, static_argnames=("metric", "tile_r", "tile_c",
                                             "feat_block", "packed",
                                             "interpret"))
def pairwise_distance_rows(x_rows, x, *, metric="braycurtis", tile_r=128,
                           tile_c=128, feat_block=128, packed: int = 0,
                           interpret: bool | None = None):
    """(block, n) distances of a row slab against the full table.

    NOTE: no diagonal zeroing — the slab does not know its global row
    offset; the streaming consumer masks the (global_row == col) entries
    (repro.pipeline.streaming does this while squaring into D²).
    packed=1: as in pairwise_distance (jaccard popcount word slabs).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if metric not in _KERNELS:
        raise ValueError(f"unknown metric {metric!r}")
    _check_packed(metric, packed)
    b = x_rows.shape[0]
    n = x.shape[0]
    if packed:
        xr_q = _dist.pack_presence_bits(x_rows)
        xc_q = _dist.pack_presence_bits(x)
        kern = _k.jaccard_packed_pallas
    else:
        xr_q = x_rows.astype(jnp.float32)
        xc_q = x.astype(jnp.float32)
        kern = _KERNELS[metric]
    d = xr_q.shape[1]
    tile_r = _pick(b, tile_r)
    tile_c = _pick(n, tile_c)
    feat_block = _pick(d, feat_block)
    b_pad = (-b) % tile_r
    n_pad = (-n) % tile_c
    d_pad = (-d) % feat_block
    xr = jnp.pad(xr_q, ((0, b_pad), (0, d_pad)))
    xc = jnp.pad(xc_q, ((0, n_pad), (0, d_pad)))
    out = kern(xr, xc, tile_r=tile_r, tile_c=tile_c,
               feat_block=feat_block, interpret=interpret)
    return out[:b, :n]
