"""jit'd wrappers for the pairwise-distance Pallas kernels (with padding)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance import kernel as _k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick(v: int, cap: int) -> int:
    t = 1
    while t * 2 <= min(v, cap):
        t *= 2
    return max(t, 8)


@functools.partial(jax.jit, static_argnames=("metric", "tile_r", "tile_c",
                                             "feat_block", "interpret"))
def pairwise_distance(x, *, metric="braycurtis", tile_r=128, tile_c=128,
                      feat_block=128, interpret: bool | None = None):
    """(n, n) distance matrix from (n, d) features via the Pallas kernels.

    Pads n/d to tile multiples; zero-padded features are exact for both
    metrics (|0-0| = 0 contributes nothing; pad rows are sliced off).
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, d = x.shape
    tile_r = _pick(n, tile_r)
    tile_c = _pick(n, tile_c)
    feat_block = _pick(d, feat_block)
    n_pad = (-n) % max(tile_r, tile_c)
    d_pad = (-d) % feat_block
    xp = jnp.pad(x.astype(jnp.float32), ((0, n_pad), (0, d_pad)))
    if metric == "braycurtis":
        out = _k.braycurtis_pallas(xp, tile_r=tile_r, tile_c=tile_c,
                                   feat_block=feat_block, interpret=interpret)
    elif metric == "euclidean":
        out = _k.euclidean_pallas(xp, tile_r=tile_r, tile_c=tile_c,
                                  feat_block=feat_block, interpret=interpret)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    out = out[:n, :n]
    return out * (1.0 - jnp.eye(n, dtype=out.dtype))  # exact zero diagonal
