"""jit'd wrappers around the permanova_sw Pallas kernels.

Handles the padding contract, variant dispatch, and interpret-mode selection
(interpret=True everywhere except a real TPU backend). These wrappers are the
`sw_fn` plug-ins for core.permanova.permanova(...).

Design subsystem note: these kernels build the one-hot factor from int
labels IN-KERNEL, so they serve every LABELS-mode design — including
strata-restricted permutations, whose labels are generated outside and
arrive through the same (n_perms, n) operand. DENSE designs (covariates /
weights / multi-factor, core.design) need the per-column basis contraction
instead; the engine registry marks these impls label-only (`cols=None`)
and the planner routes dense designs to the matmul-family companions (the
fused_sw megakernel has a native dense variant, `fused_sw_cols_pallas`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.permanova_sw import kernel as _k

VARIANTS = ("brute", "permblock", "matmul")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_inputs(mat2, groupings, *, tile_r, tile_c, perm_block):
    n_perms, n = groupings.shape
    tile = max(tile_r, tile_c)
    n_pad = (-n) % tile
    p_pad = (-n_perms) % perm_block
    if n_pad:
        mat2 = jnp.pad(mat2, ((0, n_pad), (0, n_pad)))
        groupings = jnp.pad(groupings, ((0, 0), (0, n_pad)))
    if p_pad:
        groupings = jnp.pad(groupings, ((0, p_pad), (0, 0)), mode="edge")
    return mat2, groupings, n_perms


def _auto_tiles(n: int, tile_r: int, tile_c: int):
    """Shrink tiles for small problems (tests use n << 256)."""
    t = 1
    while t * 2 <= min(n, tile_r):
        t *= 2
    return min(tile_r, max(t, 8)), min(tile_c, max(t, 8))


@functools.partial(jax.jit, static_argnames=(
    "variant", "tile_r", "tile_c", "perm_block", "interpret"))
def permanova_sw(mat2, groupings, inv_group_sizes, *, variant="matmul",
                 tile_r=256, tile_c=256, perm_block=16,
                 interpret: bool | None = None):
    """s_W for a batch of permutations via the Pallas kernel `variant`.

    mat2:            (n, n) squared distances, zero diagonal (f32 or bf16
                     for the matmul variant; accumulation is fp32).
    groupings:       (n_perms, n) int32 permuted labels.
    inv_group_sizes: (n_groups,) f32.
    Returns (n_perms,) f32.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n = mat2.shape[0]
    tile_r, tile_c = _auto_tiles(n, tile_r, tile_c)
    perm_block = min(perm_block, groupings.shape[0])
    mat2, groupings, n_perms = _pad_inputs(
        mat2, groupings, tile_r=tile_r, tile_c=tile_c, perm_block=perm_block)
    w = inv_group_sizes.astype(jnp.float32)
    if variant == "brute":
        out = _k.sw_brute_pallas(mat2, groupings, w, tile_r=tile_r,
                                 tile_c=tile_c, interpret=interpret)
    elif variant == "permblock":
        out = _k.sw_permblock_pallas(mat2, groupings, w,
                                     perm_block=perm_block, tile_r=tile_r,
                                     tile_c=tile_c, interpret=interpret)
    elif variant == "matmul":
        out = _k.sw_matmul_pallas(mat2, groupings, w, perm_block=perm_block,
                                  tile_r=tile_r, tile_c=tile_c,
                                  interpret=interpret)
    else:
        raise ValueError(f"unknown variant {variant!r}; one of {VARIANTS}")
    return out[:n_perms]


def make_sw_fn(variant: str = "matmul", **kw):
    """Adapter producing the (mat2, groupings, inv_gs) -> s_W signature that
    core.permanova.permanova(sw_fn=...) expects."""
    def fn(mat2, groupings, inv_gs):
        return permanova_sw(mat2, groupings, inv_gs, variant=variant, **kw)
    return fn
