from repro.kernels.permanova_sw.ops import permanova_sw  # noqa: F401
