"""Pure-jnp oracle for the permanova_sw Pallas kernels.

The oracle is the vectorized brute-force statistic (which the tests tie back
to the literal numpy Algorithm 1 transcription in core.fstat). All kernel
variants — brute, permblock, matmul — must match this within fp32 tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fstat

Array = jax.Array


def sw_ref(mat2: Array, groupings: Array, inv_group_sizes: Array) -> Array:
    """(n_perms,) s_W via the vectorized upper-triangle brute force."""
    return fstat.sw_brute(mat2, groupings, inv_group_sizes,
                          block=min(8, groupings.shape[0]))


def sw_ref_f64(mat2, groupings, inv_group_sizes):
    """Higher-precision reference (numpy float64) for tolerance calibration."""
    import numpy as np
    mat2 = np.asarray(mat2, np.float64)
    groupings = np.asarray(groupings)
    w = np.asarray(inv_group_sizes, np.float64)
    n = mat2.shape[0]
    triu = np.triu(np.ones((n, n), bool), k=1)
    out = []
    for g in groupings:
        same = g[:, None] == g[None, :]
        out.append(np.sum(np.where(same & triu, mat2 * w[g][:, None], 0.0)))
    return np.asarray(out)
