"""Pallas TPU kernels for the PERMANOVA pseudo-F partial statistic s_W.

Three dataflows, mirroring the paper's study (DESIGN.md section 2):

  brute      paper Algorithm 3 (the GPU winner on MI300A): grid =
             (perm, row-tile, col-tile); each permutation re-streams the
             mat^2 tiles HBM->VMEM. VPU masked square-accumulate.
             HBM traffic ~= 4 * n^2 * n_perms bytes.

  permblock  the paper's CPU tiling insight transplanted to TPU: grid =
             (perm-block, row-tile, col-tile); ONE VMEM-resident mat^2 tile
             serves a BLOCK of P permutations (VMEM plays the role of the
             MI300A's L2). HBM traffic divided by P.

  matmul     beyond-paper MXU formulation: the grouping indicator becomes a
             one-hot matmul, so each mat^2 tile feeds a (TR,TC)x(TC,G*P)
             systolic contraction. Arithmetic intensity ~P*G/2 flop/byte —
             past the v5e ridge point for P*G >= ~512 (see DESIGN.md sec. 3).

Grid convention (TPU): the LAST grid dimension is innermost. All kernels
accumulate over the (row-tile, col-tile) inner dims into an output block
indexed only by the outer perm dim — the Pallas-safe write-once-per-block
accumulation pattern (init at first inner step via pl.when).

Padding contract (enforced by ops.py): n padded to the tile multiple with
ZERO rows/cols in mat2 (zero distances contribute nothing regardless of the
pad labels); n_perms padded to the perm-block multiple by repeating the last
permutation (excess entries sliced off).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_weights(g_row, w):
    """w[g] gather via one-hot contraction (MXU/VPU-safe, G is small)."""
    n_groups = w.shape[-1]
    onehot = (g_row[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, n_groups), 1)).astype(w.dtype)
    return onehot @ w.reshape(n_groups, 1)  # (..., 1)


# ---------------------------------------------------------------------------
# brute: grid (n_perms, nti, ntj)
# ---------------------------------------------------------------------------

def _sw_brute_body(g_row_ref, g_col_ref, w_ref, m2_ref, o_ref, *,
                   tile_r: int, tile_c: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g_r = g_row_ref[0, :]                      # (TR,)
    g_c = g_col_ref[0, :]                      # (TC,)
    m2 = m2_ref[...]                           # (TR, TC)
    w = w_ref[0, :]                            # (G,)

    rows = i * tile_r + jax.lax.broadcasted_iota(jnp.int32, (tile_r, tile_c), 0)
    cols = j * tile_c + jax.lax.broadcasted_iota(jnp.int32, (tile_r, tile_c), 1)
    # strict upper triangle + same-group indicator (paper Alg. 3 inner ifs)
    mask = (g_c[None, :] == g_r[:, None]) & (cols > rows)
    local = jnp.sum(jnp.where(mask, m2, 0.0), axis=1)      # per-row local_s_W
    w_row = _row_weights(g_r, w)[:, 0]                     # hoisted weight
    o_ref[0] += jnp.sum(local * w_row)


def sw_brute_pallas(mat2, groupings, w, *, tile_r=256, tile_c=256,
                    interpret=True):
    n_perms, n = groupings.shape
    grid = (n_perms, n // tile_r, n // tile_c)
    kernel = functools.partial(_sw_brute_body, tile_r=tile_r, tile_c=tile_c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_r), lambda p, i, j: (p, i)),
            pl.BlockSpec((1, tile_c), lambda p, i, j: (p, j)),
            pl.BlockSpec((1, w.shape[-1]), lambda p, i, j: (0, 0)),
            pl.BlockSpec((tile_r, tile_c), lambda p, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1,), lambda p, i, j: (p,)),
        out_shape=jax.ShapeDtypeStruct((n_perms,), jnp.float32),
        interpret=interpret,
    )(groupings, groupings, w.reshape(1, -1), mat2)


# ---------------------------------------------------------------------------
# permblock: grid (n_perm_blocks, nti, ntj); PB perms share each mat2 tile
# ---------------------------------------------------------------------------

def _sw_permblock_body(g_row_ref, g_col_ref, w_ref, m2_ref, o_ref, *,
                       tile_r: int, tile_c: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g_r = g_row_ref[...]                       # (PB, TR)
    g_c = g_col_ref[...]                       # (PB, TC)
    m2 = m2_ref[...]                           # (TR, TC)
    w = w_ref[0, :]                            # (G,)

    rows = i * tile_r + jax.lax.broadcasted_iota(jnp.int32, (tile_r, tile_c), 0)
    cols = j * tile_c + jax.lax.broadcasted_iota(jnp.int32, (tile_r, tile_c), 1)
    tri = (cols > rows)[None, :, :]
    mask = (g_c[:, None, :] == g_r[:, :, None]) & tri      # (PB, TR, TC)
    local = jnp.sum(jnp.where(mask, m2[None, :, :], 0.0), axis=2)  # (PB, TR)
    w_row = _row_weights(g_r, w)[..., 0]                   # (PB, TR)
    o_ref[...] += jnp.sum(local * w_row, axis=1)


def sw_permblock_pallas(mat2, groupings, w, *, perm_block=8, tile_r=256,
                        tile_c=256, interpret=True):
    n_perms, n = groupings.shape
    grid = (n_perms // perm_block, n // tile_r, n // tile_c)
    kernel = functools.partial(_sw_permblock_body, tile_r=tile_r, tile_c=tile_c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((perm_block, tile_r), lambda p, i, j: (p, i)),
            pl.BlockSpec((perm_block, tile_c), lambda p, i, j: (p, j)),
            pl.BlockSpec((1, w.shape[-1]), lambda p, i, j: (0, 0)),
            pl.BlockSpec((tile_r, tile_c), lambda p, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((perm_block,), lambda p, i, j: (p,)),
        out_shape=jax.ShapeDtypeStruct((n_perms,), jnp.float32),
        interpret=interpret,
    )(groupings, groupings, w.reshape(1, -1), mat2)


# ---------------------------------------------------------------------------
# matmul: grid (n_perm_blocks, nti, ntj); MXU one-hot contraction
# ---------------------------------------------------------------------------

def _sw_matmul_body(g_row_ref, g_col_ref, sqrtw_ref, m2_ref, o_ref, *,
                    n_groups: int, acc_dtype):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g_r = g_row_ref[...]                       # (PB, TR)
    g_c = g_col_ref[...]                       # (PB, TC)
    m2 = m2_ref[...]                           # (TR, TC)
    sqrt_w = sqrtw_ref[0, :]                   # (G,)

    iota_g = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_groups), 2)
    e_col = (g_c[:, :, None] == iota_g).astype(m2.dtype) * sqrt_w  # (PB,TC,G)
    e_row = (g_r[:, :, None] == iota_g).astype(m2.dtype) * sqrt_w  # (PB,TR,G)
    # MXU contraction: (TR,TC) x (PB,TC,G) -> (PB,TR,G)
    y = jax.lax.dot_general(
        e_col, m2,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype,
    )                                           # (PB, G, TR)
    s = jnp.sum(y * jnp.transpose(e_row, (0, 2, 1)).astype(acc_dtype),
                axis=(1, 2))                    # (PB,)
    o_ref[...] += 0.5 * s.astype(jnp.float32)


def sw_matmul_pallas(mat2, groupings, w, *, perm_block=16, tile_r=256,
                     tile_c=256, n_groups=None, interpret=True):
    """Full (i != j) symmetric sum, halved — zero diagonal makes it exact.
    mat2 may be bf16 (accumulation is always fp32)."""
    n_perms, n = groupings.shape
    if n_groups is None:
        n_groups = w.shape[-1]
    grid = (n_perms // perm_block, n // tile_r, n // tile_c)
    sqrt_w = jnp.sqrt(w).astype(mat2.dtype)
    kernel = functools.partial(_sw_matmul_body, n_groups=n_groups,
                               acc_dtype=jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((perm_block, tile_r), lambda p, i, j: (p, i)),
            pl.BlockSpec((perm_block, tile_c), lambda p, i, j: (p, j)),
            pl.BlockSpec((1, n_groups), lambda p, i, j: (0, 0)),
            pl.BlockSpec((tile_r, tile_c), lambda p, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((perm_block,), lambda p, i, j: (p,)),
        out_shape=jax.ShapeDtypeStruct((n_perms,), jnp.float32),
        interpret=interpret,
    )(groupings, groupings, sqrt_w.reshape(1, -1), mat2)
