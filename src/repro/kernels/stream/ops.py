"""jit'd wrappers for the STREAM kernels."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.stream import kernel as _k

OPS = ("copy", "scale", "add", "triad")

# moved bytes per element, per STREAM convention (read + write)
BYTES_PER_ELEM = {"copy": 2, "scale": 2, "add": 3, "triad": 3}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("op", "block", "interpret"))
def stream_op(a, b, s=3.0, *, op="triad", block=65536,
              interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    n = a.shape[0]
    block = min(block, n)
    pad = (-n) % block
    if pad:
        a = jnp.pad(a, (0, pad))
        b = jnp.pad(b, (0, pad))
    out = _k.stream_pallas(a, b, s, op=op, block=block, interpret=interpret)
    return out[:n]
