"""Oracles for the STREAM kernels (paper Appendix A2)."""

import jax.numpy as jnp


def copy_ref(a, b, s):
    return a


def scale_ref(a, b, s):
    return s * a


def add_ref(a, b, s):
    return a + b


def triad_ref(a, b, s):
    return a + s * b


REFS = {"copy": copy_ref, "scale": scale_ref, "add": add_ref,
        "triad": triad_ref}
