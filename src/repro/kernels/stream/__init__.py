from repro.kernels.stream.ops import stream_op  # noqa: F401
