"""STREAM bandwidth-probe kernels (the paper's Appendix A2 methodology).

The paper calibrates its roofline with a GPU-aware STREAM variant
(copy/scale/add/triad). We carry the same probe as Pallas kernels so the
framework can measure achievable HBM bandwidth on the target chip and feed
the measured (rather than datasheet) bandwidth into the roofline model —
exactly what the paper does with its 3.0 TB/s (GPU) / 0.2 TB/s (CPU) numbers
against the 5.3 TB/s datasheet.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stream_body(a_ref, b_ref, s_ref, o_ref, *, op: str):
    a = a_ref[...]
    s = s_ref[0]
    if op == "copy":
        o_ref[...] = a
    elif op == "scale":
        o_ref[...] = s * a
    elif op == "add":
        o_ref[...] = a + b_ref[...]
    elif op == "triad":
        o_ref[...] = a + s * b_ref[...]
    else:  # pragma: no cover
        raise ValueError(op)


def stream_pallas(a, b, s, *, op: str, block=65536, interpret=True):
    (n,) = a.shape
    grid = (n // block,)
    kernel = functools.partial(_stream_body, op=op)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=interpret,
    )(a, b, jnp.asarray([s], a.dtype))
